//! Bulk-Synchronous-Parallel distributed GNN execution (paper §III-E):
//! per layer, every fog computes its partition with the AOT executable,
//! then a synchronization exchanges boundary (halo) activations before
//! the next layer — K syncs for a K-layer GNN.
//!
//! Fogs are simulated as logically-parallel workers on this host. The
//! engine-driven path (`run`) measures each fog's layer compute
//! individually; the measured path (`BatchedBspPlan` / `run_parallel`)
//! executes the sparse CSR kernels on a persistent per-fog worker pool
//! (`runtime::kernels::pool`) over a block-diagonal micro-batch, so
//! per-fog times are observed under genuine concurrency and reflect
//! kernel cost rather than thread start-up. With
//! `--kernel-threads > 1` each fog worker leads a shard helper group
//! sized from its partition volume, so a single large partition runs
//! row-parallel inside its fog (and the measured timings — hence the
//! online profiler's η-scaled replans — see the sharded costs). The
//! serving pipeline scales those times by the node's capability
//! multiplier and takes the per-layer max (the BSP barrier).

use std::borrow::Borrow;
use std::sync::Arc;

use crate::graph::{subgraph, ExchangePlan, Graph, LocalGraph};
use crate::obs::clock::Stopwatch;
use crate::obs::recorder::{Recorder, Ring};
use crate::obs::span::{Phase, SpanEvent};
use crate::runtime::csr_backend::{in_neighbor_lists, CsrPartition,
                                  InNbrLists};
use crate::runtime::kernels::{group_widths, FogJob, FogKernel,
                              FogWorkerPool, JobTrace, KernelScratch,
                              ShardExec};
use crate::runtime::{engine::EngineError, EdgeArrays, Engine,
                     WeightBundle};

/// Flight-recorder context for a traced measured execution: the
/// recorder handle plus the rings the spans land in. Built once per
/// (tenant, plan) pair and reused across micro-batches, so each pool
/// worker remains the sole producer of its wall ring (`rings[j]` is
/// written only by fog worker `j`; `coord` only by the calling
/// thread). Dropping the context detaches tracing without touching
/// the execution path.
pub struct ExecTrace {
    pub rec: Arc<Recorder>,
    /// `rings[j]` — fog `j`'s wall-clock ring (kernel + queue spans).
    pub rings: Vec<Arc<Ring>>,
    /// Coordinator-thread ring (halo-sync wall spans).
    pub coord: Arc<Ring>,
    /// Canonical tenant index the spans are attributed to.
    pub tenant: u32,
}

impl ExecTrace {
    pub fn new(rec: &Arc<Recorder>, n_fogs: usize,
               tenant: u32) -> ExecTrace {
        ExecTrace {
            rec: rec.clone(),
            rings: (0..n_fogs).map(|_| rec.ring()).collect(),
            coord: rec.ring(),
            tenant,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BspResult {
    /// Assembled [V_global, out_dim] outputs (global vertex order).
    pub outputs: Vec<f32>,
    pub out_dim: usize,
    /// host_seconds[layer][fog] — pure kernel wall-clock (intra-fog
    /// shard parallelism included, job-channel queueing excluded).
    pub layer_host_seconds: Vec<Vec<f64>>,
    /// queue_wait_s[layer][fog] — job-channel send-to-dequeue latency,
    /// reported apart from kernel seconds so profiler observations
    /// stay queueing-free (all zero on the engine-driven and serial
    /// paths, which have no job channel).
    pub layer_queue_wait_seconds: Vec<Vec<f64>>,
    /// Activation bytes exchanged at each layer boundary (total).
    pub sync_bytes: Vec<usize>,
    /// Max per-fog OUTGOING bytes at each boundary — the bottleneck of
    /// the pairwise-parallel exchange.
    pub sync_max_out: Vec<usize>,
    /// Per-fog owned-vertex counts.
    pub fog_vertices: Vec<usize>,
    /// Per-fog cardinality ⟨|V|,|N_V|⟩ (for the online profiler).
    pub fog_cardinality: Vec<(usize, usize)>,
}

/// Per-fog receiver index: global id -> halo row slot. A pure function
/// of the partition, so the batched plan precomputes it once and the
/// per-batch sync pays no structure rebuild.
type HaloIndex = Vec<std::collections::HashMap<u32, usize>>;

/// Shared plan-construction validation: known model, sane width. The
/// width bound holds on the library path too, not just CLI parsing —
/// an absurd value would otherwise panic mid-run spawning
/// n_fogs × (threads - 1) helper threads.
fn validate_plan_inputs(model: &str, kernel_threads: usize)
                        -> Result<(), EngineError> {
    if !matches!(model, "gcn" | "sage" | "gat" | "astgcn") {
        return Err(EngineError::Unsupported(format!(
            "measured batched BSP supports gcn|gat|sage|astgcn, \
             not {model}"
        )));
    }
    if kernel_threads == 0
        || kernel_threads > crate::util::cli::MAX_KERNEL_THREADS
    {
        return Err(EngineError::Unsupported(format!(
            "kernel_threads must be in 1..={} (got {kernel_threads})",
            crate::util::cli::MAX_KERNEL_THREADS
        )));
    }
    Ok(())
}

fn build_halo_index<S: Borrow<LocalGraph>>(subs: &[S]) -> HaloIndex {
    subs.iter()
        .map(|s| {
            let s = s.borrow();
            s.vertices[s.n_local..]
                .iter()
                .enumerate()
                .map(|(i, &gid)| (gid, s.n_local + i))
                .collect()
        })
        .collect()
}

/// Exchange halo activations: copy each owner's local rows into the
/// requesters' halo slots, once per batch block (states are
/// [batch * n_total, dim] block-major). Returns total bytes moved
/// between fogs across all blocks. Generic over the sub container so
/// the engine path (`Vec<LocalGraph>`) and the shared-ownership plan
/// path (`Vec<Arc<LocalGraph>>`) use the same implementation.
fn sync_halo<S: Borrow<LocalGraph>>(
    subs: &[S],
    plan: &ExchangePlan,
    halo_index: &HaloIndex,
    states: &mut [Vec<f32>],
    dim: usize,
    batch: usize,
) -> usize {
    let mut bytes = 0usize;
    for owner in 0..subs.len() {
        for req in 0..subs.len() {
            let wanted = &plan.transfers[owner][req];
            if wanted.is_empty() {
                continue;
            }
            bytes += wanted.len() * dim * 4 * batch;
            let n_owner = subs[owner].borrow().n_total();
            let n_req = subs[req].borrow().n_total();
            for &owner_local in wanted {
                let gid =
                    subs[owner].borrow().vertices[owner_local as usize];
                let pos = *halo_index[req]
                    .get(&gid)
                    .expect("halo row for shipped vertex");
                let (src, dst) = if owner == req {
                    unreachable!("no self transfers in plan");
                } else {
                    // split borrow
                    let (a, b) = if owner < req {
                        let (lo, hi) = states.split_at_mut(req);
                        (&lo[owner], &mut hi[0])
                    } else {
                        let (lo, hi) = states.split_at_mut(owner);
                        (&hi[0], &mut lo[req])
                    };
                    (a, b)
                };
                for bk in 0..batch {
                    let src0 =
                        (bk * n_owner + owner_local as usize) * dim;
                    let dst0 = (bk * n_req + pos) * dim;
                    // SAFETY NOTE: plain copy via temporaries to keep
                    // the borrow checker happy would clone; use index
                    // math on the split slices instead.
                    let tmp: Vec<f32> = src[src0..src0 + dim].to_vec();
                    dst[dst0..dst0 + dim].copy_from_slice(&tmp);
                }
            }
        }
    }
    bytes
}

/// Run a full multi-layer GNN over a placement.
///
/// * `features` — [V_global, f_in] row-major (already dequantized when a
///   codec was applied upstream).
/// * `assignment` — vertex → fog id.
#[allow(clippy::too_many_arguments)]
pub fn run(
    g: &Graph,
    features: &[f32],
    f_in: usize,
    assignment: &[u32],
    n_fogs: usize,
    model: &str,
    dataset: &str,
    classes: usize,
    engine: &mut Engine,
) -> Result<BspResult, EngineError> {
    let (subs, plan) = subgraph::extract(g, assignment, n_fogs);
    // astgcn uses the dense-adjacency path; no COO edge arrays needed
    let edges: Vec<EdgeArrays> = if model == "astgcn" {
        Vec::new()
    } else {
        subs.iter()
            .map(|s| crate::runtime::pad::prep_edges(model, s))
            .collect::<Result<Vec<_>, _>>()?
    };
    // initial states: local rows from collected features; halo zeroed
    // (filled by the first sync round)
    let mut states: Vec<Vec<f32>> = subs
        .iter()
        .map(|s| {
            let mut h = vec![0f32; s.n_total() * f_in];
            for (row, &gid) in s.vertices.iter().enumerate() {
                if row < s.n_local {
                    h[row * f_in..(row + 1) * f_in].copy_from_slice(
                        &features[gid as usize * f_in
                            ..(gid as usize + 1) * f_in],
                    );
                }
            }
            h
        })
        .collect();

    let num_layers = crate::runtime::reference::model_layers(model);
    let mut layer_host = Vec::with_capacity(num_layers);
    let mut sync_bytes = Vec::with_capacity(num_layers);
    let mut sync_max_out = Vec::with_capacity(num_layers);
    // per-fog outgoing vertex counts (placement-static)
    let out_counts: Vec<usize> = (0..n_fogs)
        .map(|owner| {
            plan.transfers[owner].iter().map(|t| t.len()).sum()
        })
        .collect();
    let max_out_vertices = out_counts.iter().copied().max().unwrap_or(0);
    let mut dim = f_in;
    let mut out_dim = f_in;
    let halo_index = build_halo_index(&subs);
    for layer in 0..num_layers {
        // sync round: ship current halo activations
        sync_bytes.push(sync_halo(&subs, &plan, &halo_index,
                                  &mut states, dim, 1));
        sync_max_out.push(max_out_vertices * dim * 4);
        let mut per_fog = Vec::with_capacity(n_fogs);
        let mut next_states: Vec<Vec<f32>> = Vec::with_capacity(n_fogs);
        for (j, sub) in subs.iter().enumerate() {
            if sub.n_total() == 0 {
                // fog holds no vertices (degenerate placement): no work
                per_fog.push(0.0);
                next_states.push(Vec::new());
                continue;
            }
            let out = if model == "astgcn" {
                engine.run_astgcn(dataset, &states[j], sub.n_total(),
                                  f_in, sub)?
            } else {
                engine.run_layer(model, dataset, layer, &states[j], dim,
                                 &edges[j], f_in, classes)?
            };
            per_fog.push(out.host_seconds);
            out_dim = out.out_dim;
            // layers emit OWNED rows only; rebuild the full local-space
            // state with halo slots zeroed — the next layer's sync round
            // fills them from their owners before any use.
            let rows = out.h.len() / out.out_dim;
            if rows == sub.n_total() {
                next_states.push(out.h);
            } else {
                debug_assert_eq!(rows, sub.n_local);
                let mut st = vec![0f32; sub.n_total() * out.out_dim];
                st[..sub.n_local * out.out_dim].copy_from_slice(&out.h);
                next_states.push(st);
            }
        }
        layer_host.push(per_fog);
        states = next_states;
        dim = out_dim;
    }

    // assemble global outputs from each fog's local rows
    let mut outputs = vec![0f32; g.num_vertices() * out_dim];
    for (j, sub) in subs.iter().enumerate() {
        for (row, &gid) in sub.vertices[..sub.n_local].iter().enumerate() {
            outputs[gid as usize * out_dim..(gid as usize + 1) * out_dim]
                .copy_from_slice(
                    &states[j][row * out_dim..(row + 1) * out_dim],
                );
        }
    }
    let layers = layer_host.len();
    Ok(BspResult {
        outputs,
        out_dim,
        layer_host_seconds: layer_host,
        layer_queue_wait_seconds: vec![vec![0.0; n_fogs]; layers],
        sync_bytes,
        sync_max_out,
        fog_vertices: subs.iter().map(|s| s.n_local).collect(),
        fog_cardinality: subs.iter().map(|s| s.cardinality()).collect(),
    })
}

/// Pre-extracted measured-execution plan for one placement: partition
/// views, the halo exchange plan, per-fog CSR structures and a
/// persistent per-fog worker pool, reusable across micro-batches — the
/// per-batch hot path pays only kernels and syncs, never partition
/// extraction or thread start-up. Covers every model: gcn|gat|sage run
/// the batched CSR layer kernels; astgcn runs the sparse-attention
/// block per batch block.
///
/// The pool is held behind an `Arc` and the workers are
/// structure-free (jobs carry their structures), so multiple plans —
/// the multi-tenant fabric's plan cache holds one per distinct
/// `(model, dataset)` — share one set of threads
/// (`with_shared_pool`), and a replan's `rebuild` swaps partition
/// structures without respawning a thread.
pub struct BatchedBspPlan {
    pub subs: Vec<Arc<LocalGraph>>,
    pub plan: ExchangePlan,
    /// One CSR per fog for the message-passing models; empty for
    /// astgcn (its kernel works on the local graph directly).
    pub csrs: Vec<Arc<CsrPartition>>,
    /// One in-neighbor structure per fog for astgcn; empty otherwise.
    /// Built once here so the per-batch hot path (and the measured
    /// timings it produces) never pays the O(V + E) counting sort.
    nbrs: Vec<Arc<InNbrLists>>,
    pool: Arc<FogWorkerPool>,
    halo_index: HaloIndex,
    model: Arc<str>,
    n_fogs: usize,
    nv: usize,
    kernel_threads: usize,
}

impl BatchedBspPlan {
    /// Single-threaded fogs (no intra-fog sharding) — the
    /// pre-`--kernel-threads` behavior.
    pub fn new(g: &Graph, assignment: &[u32], n_fogs: usize,
               model: &str) -> Result<BatchedBspPlan, EngineError> {
        BatchedBspPlan::with_threads(g, assignment, n_fogs, model, 1)
    }

    /// `kernel_threads` is the worker-group width the largest
    /// partition gets; smaller fogs get proportionally fewer workers
    /// (`kernels::pool::group_widths`). Builds a private pool; use
    /// `with_shared_pool` to reuse another plan's threads.
    pub fn with_threads(g: &Graph, assignment: &[u32], n_fogs: usize,
                        model: &str, kernel_threads: usize)
                        -> Result<BatchedBspPlan, EngineError> {
        validate_plan_inputs(model, kernel_threads)?;
        let mut volumes = vec![0usize; n_fogs];
        for &a in assignment {
            volumes[a as usize] += 1;
        }
        let pool = Arc::new(FogWorkerPool::with_widths(group_widths(
            &volumes,
            kernel_threads,
        )));
        BatchedBspPlan::with_shared_pool(g, assignment, n_fogs, model,
                                         kernel_threads, pool)
    }

    /// Build a plan on an EXISTING pool (one thread set shared across
    /// every plan holding the handle). The pool must have one worker
    /// per fog; shard widths are the pool's — kernels are
    /// row-decomposition invariant, so outputs are identical for any
    /// widths, only the parallel speedup differs.
    pub fn with_shared_pool(g: &Graph, assignment: &[u32],
                            n_fogs: usize, model: &str,
                            kernel_threads: usize,
                            pool: Arc<FogWorkerPool>)
                            -> Result<BatchedBspPlan, EngineError> {
        validate_plan_inputs(model, kernel_threads)?;
        if pool.len() != n_fogs {
            return Err(EngineError::Unsupported(format!(
                "shared pool has {} workers but the placement has \
                 {n_fogs} fogs",
                pool.len()
            )));
        }
        if pool.is_poisoned() {
            return Err(EngineError::Unsupported(
                "shared pool was poisoned by an earlier worker panic; \
                 build the plan on a fresh pool"
                    .to_string(),
            ));
        }
        let (subs, plan) = subgraph::extract(g, assignment, n_fogs);
        let subs: Vec<Arc<LocalGraph>> =
            subs.into_iter().map(Arc::new).collect();
        let csrs: Vec<Arc<CsrPartition>> = if model == "astgcn" {
            Vec::new()
        } else {
            subs.iter()
                .map(|s| {
                    crate::runtime::pad::prep_edges(model, s)
                        .map(|e| Arc::new(CsrPartition::from_edges(&e)))
                })
                .collect::<Result<Vec<_>, _>>()?
        };
        let nbrs: Vec<Arc<InNbrLists>> = if model == "astgcn" {
            subs.iter()
                .map(|s| Arc::new(in_neighbor_lists(s, s.n_total())))
                .collect()
        } else {
            Vec::new()
        };
        let halo_index = build_halo_index(&subs);
        Ok(BatchedBspPlan {
            subs,
            plan,
            csrs,
            nbrs,
            pool,
            halo_index,
            model: Arc::from(model),
            n_fogs,
            nv: g.num_vertices(),
            kernel_threads,
        })
    }

    pub fn n_fogs(&self) -> usize {
        self.n_fogs
    }

    /// The `--kernel-threads` value this plan was built with (max
    /// per-fog worker-group width).
    pub fn kernel_threads(&self) -> usize {
        self.kernel_threads
    }

    /// Handle to the persistent worker pool, for building further
    /// plans over the same threads (`with_shared_pool`).
    pub fn pool_handle(&self) -> Arc<FogWorkerPool> {
        self.pool.clone()
    }

    /// Per-fog worker-group widths (leader + shard helpers).
    pub fn widths(&self) -> &[usize] {
        self.pool.widths()
    }

    /// Per-fog cardinality ⟨|V|, |N_V|⟩ (for the online profiler).
    pub fn cardinality(&self, fog: usize) -> (usize, usize) {
        self.subs[fog].cardinality()
    }

    /// Execute a block-diagonal batch of `batch` identical-snapshot
    /// requests. Per-fog layer compute runs on the persistent worker
    /// pool — one long-lived thread per fog, mirroring the
    /// logically-parallel fog machines — so the measured per-fog
    /// wall-clock reflects real concurrency without per-batch spawn
    /// cost. `outputs` stacks [batch * V, out_dim] block-major;
    /// `layer_host_seconds[layer][fog]` is each fog's measured batched
    /// kernel time.
    pub fn execute(&self, features: &[f32], f_in: usize,
                   wb: &Arc<WeightBundle>, batch: usize) -> BspResult {
        self.execute_inner(features, f_in, wb, batch, true, true, None)
    }

    /// Like `execute` but skips global-output assembly — the serving
    /// loop only consumes the measured timings, so the O(batch·V·F)
    /// gather would be pure waste per micro-batch. `outputs` is empty.
    pub fn execute_timings(&self, features: &[f32], f_in: usize,
                           wb: &Arc<WeightBundle>, batch: usize)
                           -> BspResult {
        self.execute_inner(features, f_in, wb, batch, false, true, None)
    }

    /// `execute_timings` with flight-recorder spans: each fog worker
    /// records wall-clock `kernel`/`queue` spans into its ring and the
    /// calling thread records halo-sync spans — numerically identical
    /// to the untraced path (tracing only observes the seconds the
    /// result already reports).
    pub fn execute_timings_traced(&self, features: &[f32], f_in: usize,
                                  wb: &Arc<WeightBundle>, batch: usize,
                                  trace: Option<&ExecTrace>)
                                  -> BspResult {
        self.execute_inner(features, f_in, wb, batch, false, true,
                           trace)
    }

    /// `execute` with every fog's kernels run inline on the calling
    /// thread — the spawn-free oracle. Shares the exact kernel code
    /// path with the pooled workers (`FogJob::run`), so pooled and
    /// serial outputs are bit-identical; `tests/backend_parity.rs`
    /// asserts it.
    pub fn execute_serial(&self, features: &[f32], f_in: usize,
                          wb: &Arc<WeightBundle>, batch: usize)
                          -> BspResult {
        self.execute_inner(features, f_in, wb, batch, true, false, None)
    }

    /// Build this layer's per-fog jobs, draining `states` (fogs owning
    /// no vertices get `None`).
    #[allow(clippy::too_many_arguments)]
    fn layer_jobs(&self, layer: usize, dim: usize, last: bool,
                  batch: usize, f_in: usize,
                  states: &mut [Vec<f32>], wb: &Arc<WeightBundle>,
                  trace: Option<&ExecTrace>) -> Vec<Option<FogJob>> {
        (0..self.n_fogs)
            .map(|j| {
                if self.subs[j].n_total() == 0 {
                    return None;
                }
                let state = std::mem::take(&mut states[j]);
                let kernel = if &*self.model == "astgcn" {
                    FogKernel::Astgcn { ft: f_in }
                } else {
                    FogKernel::Layer { layer, dim, last }
                };
                Some(FogJob {
                    kernel,
                    model: self.model.clone(),
                    batch,
                    state,
                    weights: wb.clone(),
                    sub: self.subs[j].clone(),
                    csr: self.csrs.get(j).cloned(),
                    nbr: self.nbrs.get(j).cloned(),
                    trace: trace.map(|tr| JobTrace {
                        rec: tr.rec.clone(),
                        ring: tr.rings[j].clone(),
                        tenant: tr.tenant,
                        layer: layer as i32,
                    }),
                })
            })
            .collect()
    }

    /// Run one layer's jobs inline (the serial oracle). Shard widths
    /// mirror the pool's per-fog groups (`ShardExec::Inline`), so the
    /// split points — and therefore the outputs — are identical to the
    /// pooled run by construction (and row-decomposition invariance
    /// makes them split-independent besides).
    fn run_jobs_serial(&self, jobs: Vec<Option<FogJob>>)
                       -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut scratch = KernelScratch::default();
        let mut outs = Vec::with_capacity(jobs.len());
        let mut secs = Vec::with_capacity(jobs.len());
        for (j, job) in jobs.into_iter().enumerate() {
            match job {
                None => {
                    outs.push(Vec::new());
                    secs.push(0.0);
                }
                Some(job) => {
                    let exec =
                        ShardExec::Inline(self.pool.widths()[j]);
                    let (out, s) = job.run(&mut scratch, &exec);
                    outs.push(out);
                    secs.push(s);
                }
            }
        }
        (outs, secs)
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_inner(&self, features: &[f32], f_in: usize,
                     wb: &Arc<WeightBundle>, batch: usize,
                     assemble_outputs: bool, pooled: bool,
                     trace: Option<&ExecTrace>) -> BspResult {
        assert!(batch >= 1);
        let n_fogs = self.n_fogs;
        let model: &str = &self.model;
        let num_layers = crate::runtime::reference::model_layers(model);
        // initial states: every block carries the same snapshot rows
        let mut states: Vec<Vec<f32>> = self
            .subs
            .iter()
            .map(|s| {
                let n = s.n_total();
                let mut h = vec![0f32; batch * n * f_in];
                for (row, &gid) in
                    s.vertices[..s.n_local].iter().enumerate()
                {
                    let src = &features[gid as usize * f_in
                        ..(gid as usize + 1) * f_in];
                    for bk in 0..batch {
                        let at = (bk * n + row) * f_in;
                        h[at..at + f_in].copy_from_slice(src);
                    }
                }
                h
            })
            .collect();

        let mut layer_host = Vec::with_capacity(num_layers);
        let mut layer_wait = Vec::with_capacity(num_layers);
        let mut sync_bytes = Vec::with_capacity(num_layers);
        let mut sync_max_out = Vec::with_capacity(num_layers);
        let out_counts: Vec<usize> = (0..n_fogs)
            .map(|owner| {
                self.plan.transfers[owner]
                    .iter()
                    .map(|t| t.len())
                    .sum()
            })
            .collect();
        let max_out_vertices =
            out_counts.iter().copied().max().unwrap_or(0);
        let mut dim = f_in;
        let mut out_dim = f_in;
        for layer in 0..num_layers {
            let sw = trace.map(|_| Stopwatch::start());
            sync_bytes.push(sync_halo(&self.subs, &self.plan,
                                      &self.halo_index, &mut states,
                                      dim, batch));
            if let (Some(tr), Some(sw)) = (trace, sw) {
                let dur_us = sw.elapsed_s() * 1e6;
                let end_us = tr.rec.wall_now_us();
                let mut ev = SpanEvent::new(Phase::Sync, tr.tenant,
                                            end_us - dur_us, dur_us)
                    .count(batch)
                    .on_wall();
                ev.layer = layer as i32;
                tr.rec.span(&tr.coord, ev);
            }
            sync_max_out.push(max_out_vertices * dim * 4 * batch);
            let last = layer + 1 == num_layers;
            let jobs = self.layer_jobs(layer, dim, last, batch, f_in,
                                       &mut states, wb, trace);
            let (outs, secs, waits) = if pooled {
                self.pool.dispatch(jobs)
            } else {
                let (outs, secs) = self.run_jobs_serial(jobs);
                let waits = vec![0.0; secs.len()];
                (outs, secs, waits)
            };
            let mut next_states: Vec<Vec<f32>> =
                Vec::with_capacity(n_fogs);
            for (j, out) in outs.into_iter().enumerate() {
                if out.is_empty() {
                    // fog owns no vertices (n_local > 0 ⟺ n_total > 0)
                    next_states.push(Vec::new());
                    continue;
                }
                let l = self.subs[j].n_local;
                let n = self.subs[j].n_total();
                if model == "astgcn" {
                    // the astgcn kernel emits ALL rows (halos included)
                    out_dim = out.len() / (batch * n);
                    next_states.push(out);
                } else {
                    out_dim = out.len() / (batch * l);
                    // rebuild full local-space states with halo slots
                    // zeroed (filled by the next sync round)
                    let mut st = vec![0f32; batch * n * out_dim];
                    for bk in 0..batch {
                        st[bk * n * out_dim..(bk * n + l) * out_dim]
                            .copy_from_slice(
                                &out[bk * l * out_dim
                                    ..(bk + 1) * l * out_dim],
                            );
                    }
                    next_states.push(st);
                }
            }
            layer_host.push(secs);
            layer_wait.push(waits);
            states = next_states;
            dim = out_dim;
        }

        // assemble stacked global outputs [batch * V, out_dim]
        let mut outputs = if assemble_outputs {
            vec![0f32; batch * self.nv * out_dim]
        } else {
            Vec::new()
        };
        if assemble_outputs {
            for (j, sub) in self.subs.iter().enumerate() {
                let n = sub.n_total();
                for bk in 0..batch {
                    for (row, &gid) in
                        sub.vertices[..sub.n_local].iter().enumerate()
                    {
                        let at =
                            (bk * self.nv + gid as usize) * out_dim;
                        let from = (bk * n + row) * out_dim;
                        outputs[at..at + out_dim].copy_from_slice(
                            &states[j][from..from + out_dim],
                        );
                    }
                }
            }
        }
        BspResult {
            outputs,
            out_dim,
            layer_host_seconds: layer_host,
            layer_queue_wait_seconds: layer_wait,
            sync_bytes,
            sync_max_out,
            fog_vertices: self.subs.iter().map(|s| s.n_local).collect(),
            fog_cardinality: self
                .subs
                .iter()
                .map(|s| s.cardinality())
                .collect(),
        }
    }
}

/// One-shot measured batched run: extract + execute. The outputs stack
/// [batch * V, out_dim]; every block is a forward over the same
/// snapshot, so blocks are numerically identical (asserted by
/// tests/backend_parity.rs).
#[allow(clippy::too_many_arguments)]
pub fn run_parallel(
    g: &Graph,
    features: &[f32],
    f_in: usize,
    assignment: &[u32],
    n_fogs: usize,
    model: &str,
    dataset: &str,
    classes: usize,
    engine: &mut Engine,
    batch: usize,
) -> Result<BspResult, EngineError> {
    let plan = BatchedBspPlan::new(g, assignment, n_fogs, model)?;
    let wb =
        Arc::new(engine.weights(model, dataset, f_in, classes).clone());
    Ok(plan.execute(features, f_in, &wb, batch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::runtime::{Engine, EngineKind};

    /// THE distributed-correctness invariant: a k-way BSP run must produce
    /// bit-identical outputs to the single-fog run for every model.
    #[test]
    fn distributed_equals_single_fog() {
        let (mut g, _) = generate::sbm(300, 1200, 4, 0.85, 3);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(9);
        g.features =
            (0..300 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        for model in ["gcn", "sage", "gat"] {
            let mut eng = Engine::new(EngineKind::Reference, &dir).unwrap();
            let single = run(&g, &g.features, f_in, &vec![0; 300], 1,
                             model, "tiny", 3, &mut eng)
                .unwrap();
            let assignment: Vec<u32> =
                (0..300).map(|v| (v % 3) as u32).collect();
            let multi = run(&g, &g.features, f_in, &assignment, 3, model,
                            "tiny", 3, &mut eng)
                .unwrap();
            assert_eq!(single.out_dim, multi.out_dim);
            let max_err = single
                .outputs
                .iter()
                .zip(&multi.outputs)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(
                max_err < 2e-4,
                "{model}: distributed deviates by {max_err}"
            );
        }
    }

    #[test]
    fn sync_bytes_match_exchange_plan() {
        let (mut g, _) = generate::sbm(200, 800, 4, 0.9, 5);
        let f_in = 4;
        g.features = vec![1.0; 200 * f_in];
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Reference, &dir).unwrap();
        let assignment: Vec<u32> = (0..200).map(|v| (v % 2) as u32).collect();
        let res = run(&g, &g.features, f_in, &assignment, 2, "gcn",
                      "tiny", 3, &mut eng)
            .unwrap();
        let (_, plan) = subgraph::extract(&g, &assignment, 2);
        assert_eq!(res.sync_bytes.len(), 2); // K = 2 layers
        assert_eq!(res.sync_bytes[0], plan.total_vertices() * f_in * 4);
        // hidden dim 64 at the second boundary
        assert_eq!(res.sync_bytes[1], plan.total_vertices() * 64 * 4);
        // pairwise-parallel bottleneck is at most the total
        assert!(res.sync_max_out[0] <= res.sync_bytes[0]);
        assert!(res.sync_max_out[0] >= res.sync_bytes[0] / 2);
        assert_eq!(res.fog_vertices, vec![100, 100]);
    }

    #[test]
    fn astgcn_runs_distributed() {
        let (mut g, _) = generate::sbm(60, 200, 3, 0.8, 7);
        let ft = 36;
        let mut rng = crate::util::rng::Rng::new(11);
        g.features =
            (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = ft;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Reference, &dir).unwrap();
        let assignment: Vec<u32> = (0..60).map(|v| (v % 2) as u32).collect();
        let res = run(&g, &g.features, ft, &assignment, 2, "astgcn",
                      "tinypems", 0, &mut eng)
            .unwrap();
        assert_eq!(res.out_dim, 12);
        assert!(res.outputs.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batched_plan_serves_astgcn() {
        let (mut g, _) = generate::sbm(60, 200, 3, 0.8, 7);
        let ft = 36;
        let mut rng = crate::util::rng::Rng::new(12);
        g.features =
            (0..60 * ft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = ft;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..60).map(|v| (v % 2) as u32).collect();
        let batch = 2;
        let res = run_parallel(&g, &g.features, ft, &assignment, 2,
                               "astgcn", "tinypems", 0, &mut eng, batch)
            .unwrap();
        assert_eq!(res.out_dim, 12);
        assert_eq!(res.outputs.len(), batch * 60 * 12);
        assert!(res.outputs.iter().all(|v| v.is_finite()));
        // one layer, one timing per fog
        assert_eq!(res.layer_host_seconds.len(), 1);
        assert_eq!(res.layer_host_seconds[0].len(), 2);
        // both blocks are the same snapshot forward
        assert_eq!(&res.outputs[..60 * 12], &res.outputs[60 * 12..]);
    }

    #[test]
    fn unknown_model_is_rejected_by_plan() {
        let (g, _) = generate::sbm(40, 120, 2, 0.8, 3);
        let assignment = vec![0u32; 40];
        let r = BatchedBspPlan::new(&g, &assignment, 1, "mlp");
        assert!(r.is_err());
        let r = BatchedBspPlan::with_threads(&g, &assignment, 1,
                                             "gcn", 0);
        assert!(r.is_err(), "0 kernel threads is rejected");
    }

    /// Two plans over different placements sharing ONE pool must each
    /// produce exactly what a private-pool plan produces — the
    /// multi-tenant plan-cache contract.
    #[test]
    fn shared_pool_plans_match_private_pool_plans() {
        let (mut g, _) = generate::sbm(200, 800, 3, 0.85, 5);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(31);
        g.features =
            (0..200 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let a2: Vec<u32> = (0..200).map(|v| (v % 2) as u32).collect();
        let a2b: Vec<u32> =
            (0..200).map(|v| ((v / 7) % 2) as u32).collect();
        let wb_g = std::sync::Arc::new(
            eng.weights("gcn", "tiny", f_in, 3).clone(),
        );
        let wb_s = std::sync::Arc::new(
            eng.weights("sage", "tiny", f_in, 3).clone(),
        );
        let base =
            BatchedBspPlan::with_threads(&g, &a2, 2, "gcn", 2).unwrap();
        let pool = base.pool_handle();
        // a second model + a different placement on the SAME pool
        let shared = BatchedBspPlan::with_shared_pool(
            &g, &a2b, 2, "sage", 2, pool.clone(),
        )
        .unwrap();
        let private =
            BatchedBspPlan::with_threads(&g, &a2b, 2, "sage", 2)
                .unwrap();
        let rb = base.execute(&g.features, f_in, &wb_g, 4);
        let rs = shared.execute(&g.features, f_in, &wb_s, 4);
        let rp = private.execute(&g.features, f_in, &wb_s, 4);
        assert_eq!(rs.outputs, rp.outputs,
                   "shared-pool plan deviates from private-pool plan");
        // interleaving plans on the pool does not cross wires
        let rb2 = base.execute(&g.features, f_in, &wb_g, 4);
        assert_eq!(rb.outputs, rb2.outputs);
        // fog-count mismatch is rejected, not a hang
        assert!(BatchedBspPlan::with_shared_pool(
            &g, &a2b, 3, "gcn", 2, pool
        )
        .is_err());
    }

    /// Intra-fog sharding must not change a single output bit:
    /// 4-wide pooled == its serial oracle == the 1-wide plan, at a
    /// batch size that genuinely shards (batch · n_local clears
    /// MIN_ROWS_PER_SHARD).
    #[test]
    fn sharded_plan_is_bit_identical_to_single_threaded() {
        let (mut g, _) = generate::sbm(300, 1200, 4, 0.85, 3);
        let f_in = 8;
        let mut rng = crate::util::rng::Rng::new(21);
        g.features =
            (0..300 * f_in).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        g.feature_dim = f_in;
        let dir = std::env::temp_dir().join("bsp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut eng = Engine::new(EngineKind::Csr, &dir).unwrap();
        let assignment: Vec<u32> =
            (0..300).map(|v| (v % 3) as u32).collect();
        let batch = 8;
        for model in ["gcn", "gat"] {
            let wb = std::sync::Arc::new(
                eng.weights(model, "tiny", f_in, 3).clone(),
            );
            let p1 = BatchedBspPlan::new(&g, &assignment, 3, model)
                .unwrap();
            let p4 = BatchedBspPlan::with_threads(&g, &assignment, 3,
                                                  model, 4)
                .unwrap();
            assert_eq!(p4.kernel_threads(), 4);
            let r1 = p1.execute(&g.features, f_in, &wb, batch);
            let r4 = p4.execute(&g.features, f_in, &wb, batch);
            let rs = p4.execute_serial(&g.features, f_in, &wb, batch);
            assert_eq!(r4.outputs, rs.outputs,
                       "{model}: pooled-sharded != serial oracle");
            assert_eq!(r4.outputs, r1.outputs,
                       "{model}: sharded != single-threaded");
            // queue waits are reported apart from kernel seconds
            assert_eq!(r4.layer_queue_wait_seconds.len(),
                       r4.layer_host_seconds.len());
            assert!(r4
                .layer_queue_wait_seconds
                .iter()
                .flatten()
                .all(|&w| w >= 0.0));
            assert!(rs
                .layer_queue_wait_seconds
                .iter()
                .flatten()
                .all(|&w| w == 0.0));
        }
    }
}
