//! Distributed execution runtime (paper §III-E): BSP layer loop with
//! halo-exchange synchronization between GNN layers.

pub mod bsp;

pub use bsp::{run as run_bsp, BspResult};
