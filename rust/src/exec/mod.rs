//! Distributed execution runtime (paper §III-E): BSP layer loop with
//! halo-exchange synchronization between GNN layers, in two flavors —
//! the engine-driven serial loop (`run_bsp`) and the measured batched
//! path (`run_parallel` / `BatchedBspPlan`) that executes sparse CSR
//! kernels on one `std::thread` worker per fog.

pub mod bsp;

pub use bsp::{run as run_bsp, run_parallel, BatchedBspPlan, BspResult};
