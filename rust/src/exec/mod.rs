//! Distributed execution runtime (paper §III-E): BSP layer loop with
//! halo-exchange synchronization between GNN layers, in two flavors —
//! the engine-driven serial loop (`run_bsp`) and the measured batched
//! path (`run_parallel` / `BatchedBspPlan`) that executes sparse CSR
//! kernels on a persistent per-fog worker pool
//! (`runtime::kernels::pool`), so per-batch timings reflect kernel
//! cost rather than thread start-up.

pub mod bsp;

pub use bsp::{build_halo_index, run as run_bsp, run_parallel, sync_halo,
              BatchedBspPlan, BspPipeline, BspResult, ExecTrace,
              HaloIndex, PipelineChaos};
