//! `repro churn` — the streaming-graph tier (ROADMAP item 2): sweeps
//! seeded rmat / road graphs under a mixed topology-mutation trace and
//! races the incremental topology engine against the full-rebuild
//! baseline a static-topology system would have to run:
//!
//! * **headline phase** (~1% churn per round) — every round applies
//!   the deltas in place ([`TopologyEngine::churn_round`]: tombstoned
//!   CSR edits, boundary-only refinement, partition-scoped
//!   re-grounding) and then times the baseline doing the same round's
//!   work from scratch (rebuild the CSR, multilevel repartition,
//!   re-ground every fog, rebuild the collection index). The recorded
//!   speedup must clear [`SPEEDUP_GATE`]x at the top tier (non-smoke).
//! * **trickle phase** (a single delta per round) — proves the
//!   invalidation is actually partition-scoped: every round must
//!   leave fogs bit-identical (`preserved > 0`), and the per-fog
//!   feature-store blocks refreshed ONLY for dirty fogs must still
//!   match the engine's state for every fog afterwards.
//!
//! Both phases run the full bit-parity gate each round
//! ([`TopologyEngine::parity_check`]: sub-CSRs, exchange plan,
//! fingerprints vs a from-scratch rebuild) plus a served-output gate
//! (one BSP neighbor-sum round, bitwise f32 comparison) and a
//! collection-index parity gate. Results land in BENCH_churn.json plus
//! a provenance-stamped line in BENCH_history.jsonl; any gate
//! violation fails the command.

use std::io::Write;

use crate::compress::Codec;
use crate::graph::delta::{bsp_aggregate, ChurnPlan, ChurnSpec,
                          TopologyEngine};
use crate::graph::subgraph;
use crate::graph::{generate, Graph};
use crate::obs::clock::Stopwatch;
use crate::partition::{partition, MultilevelParams};
use crate::serving::collection::CollectionIndex;
use crate::serving::store::FeatureStore;
use crate::util::cli::Args;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::provenance::{git_rev, peak_rss_bytes,
                              utc_date_string};
use crate::util::rng::Rng;

/// Feature width for the served-output gate (small: topology, not
/// payload, is this tier's axis).
const DIMS: usize = 8;
/// Headline churn rounds per point.
const ROUNDS: usize = 2;
/// Trickle rounds per point.
const TRICKLE_ROUNDS: usize = 3;
/// Required incremental-over-rebuild speedup at the top tier.
const SPEEDUP_GATE: f64 = 10.0;

/// ~1% of live vertices mutated per round, mixed across all four ops.
fn headline_specs() -> Vec<ChurnSpec> {
    ["add-edge@rate=0.004", "del-edge@rate=0.003",
     "add-vertex@rate=0.002,degree=3", "del-vertex@rate=0.001"]
        .iter()
        .map(|t| ChurnSpec::parse(t).expect("static spec"))
        .collect()
}

/// One delta per round: floor(rate * live) clamps to 1, so each round
/// touches the minimum possible fog set.
fn trickle_specs() -> Vec<ChurnSpec> {
    vec![ChurnSpec::parse("del-edge@rate=0.0000001")
        .expect("static spec")]
}

struct Point {
    topology: &'static str,
    vertices: usize,
    edges: usize,
}

fn sweep(smoke: bool) -> Vec<Point> {
    let mut pts = Vec::new();
    let rmat_v: &[usize] = if smoke {
        &[16_384, 32_768]
    } else {
        &[262_144, 1_048_576]
    };
    for &v in rmat_v {
        pts.push(Point { topology: "rmat", vertices: v, edges: 4 * v });
    }
    let road_v: &[usize] =
        if smoke { &[16_384] } else { &[524_288] };
    for &v in road_v {
        pts.push(Point {
            topology: "road",
            vertices: v,
            edges: v + v / 4,
        });
    }
    pts
}

fn generate_graph(p: &Point) -> Graph {
    match p.topology {
        "rmat" => generate::rmat(p.vertices, p.edges, 11,
                                 (0.57, 0.19, 0.19, 0.05)),
        "road" => generate::road_network(p.vertices, p.edges, 4, 13).0,
        other => unreachable!("unknown topology {other}"),
    }
}

fn rss_json() -> Json {
    match peak_rss_bytes() {
        Some(b) => num(b as f64),
        None => Json::Null,
    }
}

/// Grow the global feature table to the engine's universe (appended
/// vertices read zero rows, deterministically).
fn grow_features(features: &mut Vec<f32>, nv: usize) {
    if features.len() < nv * DIMS {
        features.resize(nv * DIMS, 0.0);
    }
}

/// The full-rebuild baseline for one round: rebuild the live CSR from
/// scratch, multilevel-repartition it, re-ground every fog, rebuild
/// the collection index. Returns wall seconds.
fn rebuild_round_s(engine: &TopologyEngine, fogs: usize) -> f64 {
    let t = Stopwatch::start();
    let rebuilt = engine.csr.to_graph();
    let part = partition(&rebuilt, fogs, &MultilevelParams::default());
    let (subs, plan) =
        subgraph::extract_materialized(&rebuilt, &part.assignment,
                                       fogs);
    let idx =
        CollectionIndex::build(&rebuilt, &part.assignment, fogs);
    let s = t.elapsed_s();
    // keep the arms honest: the baseline's outputs must not be
    // optimized away, and a rebuild that lost vertices is a bug
    assert_eq!(subs.len(), fogs);
    assert!(plan.total_vertices() < usize::MAX);
    assert_eq!(
        idx.by_fog.iter().map(Vec::len).sum::<usize>(),
        rebuilt.num_vertices()
    );
    s
}

/// Every-round correctness gates: full bit parity (subs, plan,
/// fingerprints), collection-index parity, and one bitwise-compared
/// BSP round over the current features.
fn round_gates(engine: &TopologyEngine, features: &[f32], fogs: usize,
               what: &str) -> Result<(), String> {
    engine
        .parity_check()
        .map_err(|e| format!("{what}: {e}"))?;
    let rebuilt = engine.csr.to_graph();
    let ref_idx =
        CollectionIndex::build(&rebuilt, &engine.assignment, fogs);
    let (by_fog, degrees) = engine.collection_rows();
    if ref_idx.by_fog != by_fog || ref_idx.degrees != degrees {
        return Err(format!(
            "{what}: incremental collection rows != rebuilt index"
        ));
    }
    let (ref_subs, ref_plan) =
        subgraph::extract_materialized(&rebuilt, &engine.assignment,
                                       fogs);
    let served = bsp_aggregate(&engine.subs, &engine.plan,
                               &engine.assignment, features, DIMS);
    let ref_served = bsp_aggregate(&ref_subs, &ref_plan,
                                   &engine.assignment, features, DIMS);
    let bitwise = served.len() == ref_served.len()
        && served
            .iter()
            .zip(&ref_served)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    if !bitwise {
        return Err(format!(
            "{what}: served outputs differ from rebuilt (bitwise f32)"
        ));
    }
    Ok(())
}

/// Refresh per-fog feature-store blocks for the fogs a round dirtied
/// (one block per fog: owned rows + live degrees), then demand every
/// fog's stored block — refreshed or untouched — matches the engine.
fn store_gate(stores: &mut [FeatureStore], engine: &TopologyEngine,
              features: &[f32], dirty: &[u32], what: &str)
              -> Result<usize, String> {
    let (by_fog, degrees) = engine.collection_rows();
    let mut refreshed = 0usize;
    for &j in dirty {
        let j = j as usize;
        let mut rows =
            Vec::with_capacity(by_fog[j].len() * DIMS);
        for &v in &by_fog[j] {
            let v = v as usize;
            rows.extend_from_slice(
                &features[v * DIMS..(v + 1) * DIMS]);
        }
        stores[j].insert(0, rows, degrees[j].clone());
        refreshed += 1;
    }
    for (j, store) in stores.iter_mut().enumerate() {
        let rows = store.get(0);
        let want_rows = by_fog[j].len() * DIMS;
        if rows.len() != want_rows {
            return Err(format!(
                "{what}: fog {j} store holds {} rows-bytes, engine \
                 owns {want_rows}",
                rows.len()
            ));
        }
        for (i, &v) in by_fog[j].iter().enumerate() {
            let v = v as usize;
            let got = &rows[i * DIMS..(i + 1) * DIMS];
            let want = &features[v * DIMS..(v + 1) * DIMS];
            if got
                .iter()
                .zip(want)
                .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(format!(
                    "{what}: fog {j} stale store row for vertex {v} \
                     (partition-scoped store invalidation missed it)"
                ));
            }
        }
    }
    Ok(refreshed)
}

struct PointOutcome {
    row: Json,
    speedup: f64,
    trickle_preserved: u64,
}

fn run_point(p: &Point, fogs: usize) -> Result<PointOutcome, String> {
    let g = generate_graph(p);
    let nv = g.num_vertices();
    let mut rng = Rng::new(29 + nv as u64);
    let mut features: Vec<f32> =
        (0..nv * DIMS).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let part = partition(&g, fogs, &MultilevelParams::default());

    // ---- headline phase: ~1% churn, incremental vs full rebuild -----
    let mut engine = TopologyEngine::new(&g, &part.assignment, fogs);
    let mut plan = ChurnPlan::new(&headline_specs(), 41 + nv as u64);
    let mut incr_s = 0f64;
    let mut rebuild_s = 0f64;
    let mut deltas = 0usize;
    for round in 0..ROUNDS {
        let rep = engine.churn_round(&mut plan);
        let t = Stopwatch::start();
        let (by_fog, degrees) = engine.collection_rows();
        let _idx = CollectionIndex::from_parts(by_fog, degrees);
        incr_s += rep.apply_s + t.elapsed_s();
        deltas += rep.deltas;
        grow_features(&mut features, engine.csr.num_vertices());
        rebuild_s += rebuild_round_s(&engine, fogs);
        round_gates(&engine, &features, fogs,
                    &format!("{} V={nv} headline round {round}",
                             p.topology))?;
    }
    let speedup = rebuild_s / incr_s.max(1e-12);
    let headline = engine.summary();

    // ---- trickle phase: one delta per round, preservation gates -----
    let mut engine = TopologyEngine::new(&g, &part.assignment, fogs);
    let mut plan = ChurnPlan::new(&trickle_specs(), 43 + nv as u64);
    let mut features: Vec<f32> =
        (0..nv * DIMS).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut stores: Vec<FeatureStore> = (0..fogs)
        .map(|_| FeatureStore::new(1, DIMS, None, Codec::Lz4Only))
        .collect();
    // seed every store from the grounded state (round "-1": all dirty)
    let all: Vec<u32> = (0..fogs as u32).collect();
    store_gate(&mut stores, &engine, &features, &all,
               &format!("{} V={nv} trickle seed", p.topology))?;
    let mut trickle_preserved = 0u64;
    let mut blocks_refreshed = 0usize;
    for round in 0..TRICKLE_ROUNDS {
        let what =
            format!("{} V={nv} trickle round {round}", p.topology);
        let rep = engine.churn_round(&mut plan);
        if rep.preserved == 0 {
            return Err(format!(
                "{what}: single-delta round preserved no fog — \
                 invalidation is not partition-scoped"
            ));
        }
        trickle_preserved += rep.preserved as u64;
        grow_features(&mut features, engine.csr.num_vertices());
        // stores: refresh exactly the structurally-dirty fogs (owned
        // rows/degrees only move there), then verify all of them
        blocks_refreshed += store_gate(&mut stores, &engine,
                                       &features, &rep.dirty, &what)?;
        round_gates(&engine, &features, fogs, &what)?;
    }
    let trickle = engine.summary();
    if trickle.stats.partial_rounds != TRICKLE_ROUNDS as u64 {
        return Err(format!(
            "{} V={nv}: {} of {TRICKLE_ROUNDS} trickle rounds were \
             partial",
            p.topology, trickle.stats.partial_rounds
        ));
    }

    println!(
        "{:>4} V={nv:>8} E={:>8}  incr {:>8.4}s vs rebuild \
         {:>8.3}s  ({speedup:>6.1}x)  trickle preserved \
         {trickle_preserved}/{} fog-rounds",
        p.topology,
        g.num_edges(),
        incr_s,
        rebuild_s,
        TRICKLE_ROUNDS * fogs,
    );

    let row = obj(vec![
        ("topology", s(p.topology)),
        ("vertices", num(nv as f64)),
        ("edges", num(g.num_edges() as f64)),
        ("fogs", num(fogs as f64)),
        ("dims", num(DIMS as f64)),
        ("rounds", num(ROUNDS as f64)),
        ("deltas", num(deltas as f64)),
        ("incremental_s", num(incr_s)),
        ("rebuild_s", num(rebuild_s)),
        ("speedup", num(speedup)),
        ("headline_churn", headline.json()),
        ("trickle_rounds", num(TRICKLE_ROUNDS as f64)),
        (
            "trickle_preserved_fog_rounds",
            num(trickle_preserved as f64),
        ),
        (
            "trickle_store_blocks_refreshed",
            num(blocks_refreshed as f64),
        ),
        ("trickle_churn", trickle.json()),
    ]);
    Ok(PointOutcome { row, speedup, trickle_preserved })
}

pub fn cmd(args: &Args) -> i32 {
    let smoke = args.has("smoke");
    let out_path = args.get_or("out", "BENCH_churn.json");
    let history_path = args.get_or("history", "BENCH_history.jsonl");
    let fogs = match args.get("fogs") {
        None => 6,
        Some(v) => match crate::util::cli::parse_bounded_usize(
            "--fogs", v, 2, 64) {
            Ok(n) => n,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    if let Err(e) = crate::util::cli::probe_writable(out_path) {
        eprintln!("--out: {e}");
        return 2;
    }
    if let Err(e) = crate::util::cli::probe_writable(history_path) {
        eprintln!("--history: {e}");
        return 2;
    }

    let points = sweep(smoke);
    let top_v =
        points.iter().map(|p| p.vertices).max().unwrap_or(0);
    println!(
        "churn sweep: {} points, {fogs} fogs, dims {DIMS}, \
         {ROUNDS} headline + {TRICKLE_ROUNDS} trickle rounds",
        points.len()
    );

    let mut rows = Vec::new();
    let mut top_outcome: Option<PointOutcome> = None;
    for p in &points {
        match run_point(p, fogs) {
            Ok(out) => {
                let is_top =
                    p.topology == "rmat" && p.vertices == top_v;
                rows.push(out.row.clone());
                if is_top {
                    top_outcome = Some(out);
                }
            }
            Err(e) => {
                eprintln!("CHURN GATE FAIL: {e}");
                return 1;
            }
        }
    }
    let top = top_outcome.expect("sweep always has the rmat top");
    // the headline perf gate holds at the top tier only on the full
    // sweep: smoke graphs are too small for the rebuild arm's
    // asymptotics to dominate timer noise
    if !smoke && top.speedup < SPEEDUP_GATE {
        eprintln!(
            "CHURN GATE FAIL: top-tier incremental speedup {:.1}x \
             below the {SPEEDUP_GATE}x gate",
            top.speedup
        );
        return 1;
    }

    let date = utc_date_string();
    let rev = git_rev();
    let doc = obj(vec![
        ("benchmark", s("churn")),
        ("generated_by", s("repro churn")),
        ("rev", s(&rev)),
        ("date", s(&date)),
        ("smoke", Json::Bool(smoke)),
        ("fogs", num(fogs as f64)),
        ("dims", num(DIMS as f64)),
        ("speedup_gate", num(SPEEDUP_GATE)),
        ("sweep", arr(rows)),
        ("peak_rss_bytes", rss_json()),
    ]);
    if let Err(e) = std::fs::write(out_path, format!("{doc}\n")) {
        eprintln!("cannot write {out_path}: {e}");
        return 1;
    }
    println!("wrote {out_path}");

    let line = obj(vec![
        ("date", s(&date)),
        ("rev", s(&rev)),
        ("benchmark", s("churn")),
        ("smoke", Json::Bool(smoke)),
        ("fogs", num(fogs as f64)),
        ("top_vertices", num(top_v as f64)),
        ("top_speedup", num(top.speedup)),
        (
            "top_trickle_preserved_fog_rounds",
            num(top.trickle_preserved as f64),
        ),
        ("peak_rss_bytes", rss_json()),
    ]);
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(history_path)
        .and_then(|mut fh| writeln!(fh, "{line}"));
    match appended {
        Ok(()) => {
            println!("appended {history_path}");
            0
        }
        Err(e) => {
            eprintln!("cannot append {history_path}: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_monotone_and_reaches_a_million() {
        for smoke in [true, false] {
            let pts = sweep(smoke);
            for topo in ["rmat", "road"] {
                let vs: Vec<usize> = pts
                    .iter()
                    .filter(|p| p.topology == topo)
                    .map(|p| p.vertices)
                    .collect();
                assert!(!vs.is_empty());
                assert!(vs.windows(2).all(|w| w[0] < w[1]), "{topo}");
            }
            if !smoke {
                assert!(pts.iter().any(|p| p.vertices >= 1_000_000));
            }
        }
    }

    #[test]
    fn static_specs_parse() {
        assert_eq!(headline_specs().len(), 4);
        assert_eq!(trickle_specs().len(), 1);
    }

    #[test]
    fn micro_point_end_to_end_gates_hold() {
        // a micro point through the exact sweep path: every parity,
        // collection, served-output, store and preservation gate
        let p = Point {
            topology: "rmat",
            vertices: 4_096,
            edges: 4 * 4_096,
        };
        let out = run_point(&p, 4).expect("gates hold");
        assert!(out.trickle_preserved > 0);
        assert!(out.speedup > 0.0);
    }
}
