//! Fig. 15 — ablation of the two core modules: Fograph without the IEP
//! (straw-man placement + CO), without the CO (IEP + raw upload), and the
//! full system, vs the straw-man fog baseline; plus the comm/exec ratio
//! shift each module causes.

use crate::compress::Codec;
use crate::fog::Cluster;
use crate::net::NetKind;
use crate::serving::{Placement, ServeOpts};

use super::context::Ctx;
use super::tables::{f3, pct, Table};

pub fn run(ctx: &mut Ctx) -> String {
    let g = ctx.graph("siot").clone();
    let cluster = Cluster::case_study(NetKind::Cell4G);
    let variants: Vec<(&str, Placement, Codec)> = vec![
        ("fog (straw-man)", Placement::MetisRandom(4), Codec::None),
        ("fograph w/o IEP", Placement::MetisRandom(4),
         ServeOpts::co_codec(&g)),
        ("fograph w/o CO", Placement::Iep, Codec::None),
        ("fograph (full)", Placement::Iep, ServeOpts::co_codec(&g)),
    ];
    let mut t = Table::new(&[
        "variant", "latency (s)", "normalized", "comm share", "exec share",
    ]);
    let mut base = 0.0;
    let mut rows = Vec::new();
    for (name, placement, codec) in variants {
        let opts = ServeOpts::new("gcn", placement, codec);
        let r = ctx.run("siot", &cluster, &opts);
        if base == 0.0 {
            base = r.total_s;
        }
        rows.push((name, r));
    }
    for (name, r) in &rows {
        t.row(vec![
            (*name).into(),
            f3(r.total_s),
            format!("{:.3}", r.total_s / base),
            pct(r.comm_fraction()),
            pct(1.0 - r.comm_fraction()),
        ]);
    }
    format!(
        "## Fig. 15 — ablation: IEP and CO contributions (SIoT, GCN, 4G, \
         1A+2B+1C)\n\n{}\n\
         Expected shape: IEP shrinks the execution share, CO shrinks the\n\
         communication share, and the full system compounds both.\n",
        t.to_markdown()
    )
}
