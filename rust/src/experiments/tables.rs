//! Markdown/ASCII table renderer for experiment reports.

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn speedup(base: f64, ours: f64) -> String {
    if ours <= 0.0 {
        return "-".into();
    }
    format!("{:.2}x", base / ours)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(&["system", "latency (s)"]);
        t.row(vec!["cloud".into(), "2.13".into()]);
        t.row(vec!["fograph".into(), "0.41".into()]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("system"));
        assert!(lines[1].starts_with("|--"));
        // all lines same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_wrong_arity() {
        Table::new(&["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f3(1.23456), "1.235");
        assert_eq!(pct(0.824), "82.4%");
        assert_eq!(speedup(4.0, 2.0), "2.00x");
    }
}
