//! Fig. 16 — adaptive workload scheduler under a production-like load
//! trace: 1000 timesteps, one node's background load ramps up and
//! releases; Fograph with the dual-mode scheduler vs the static-placement
//! ablation.
//!
//! Per-step execution latency is evaluated analytically through the
//! calibrated ω models under the trace's load multipliers (the same model
//! the scheduler itself consumes); collection/sync costs come from one
//! real end-to-end run of the initial layout.

use crate::fog::{Cluster, LoadTrace};
use crate::net::NetKind;
use crate::profile::PerfModel;
use crate::scheduler::{diffusion, schedule, SchedulerConfig,
                       SchedulerDecision};
use crate::serving::{Placement, ServeOpts};

use super::context::Ctx;
use super::tables::{f3, pct, Table};

pub fn run(ctx: &mut Ctx) -> String {
    let dataset = "siot";
    let model = "gcn";
    let g = ctx.graph(dataset).clone();
    let spec = ctx.spec(dataset);
    let cluster = Cluster::case_study(NetKind::Wifi);
    let n = cluster.len();
    let opts = ServeOpts::new(model, Placement::Iep,
                              ServeOpts::co_codec(&g));
    let host_omega = ctx.omega(model, dataset);
    let omegas = vec![host_omega.clone(); n];

    // initial IEP layout + one real run for the comm-side constants
    let assignment0 = crate::serving::pipeline::place(
        &g, &cluster, &opts, &omegas, &spec,
    );
    let base = ctx.run(dataset, &cluster, &opts);
    let comm_const = base.collection_s + base.sync_s + base.unpack_s;

    let trace = LoadTrace::fig16(n, 1000, 0xF16);
    let scaled = |j: usize, load: f64| -> PerfModel {
        let m = cluster.nodes[j].node_type.cpu_multiplier()
            / (1.0 - load.clamp(0.0, 0.85));
        PerfModel {
            beta_v: host_omega.beta_v * m,
            beta_n: host_omega.beta_n * m,
            intercept: host_omega.intercept * m,
            r2: host_omega.r2,
        }
    };
    let latency_of = |assign: &[u32], loads: &[f64]| -> f64 {
        let models: Vec<PerfModel> =
            (0..n).map(|j| scaled(j, loads[j])).collect();
        let times = diffusion::estimate_times(&g, assign, n, &models);
        comm_const + times.iter().cloned().fold(0f64, f64::max)
    };

    let static_assign = assignment0.clone();
    let mut dyn_assign = assignment0.clone();
    let cfg = SchedulerConfig::default();
    let mut csv = String::from(
        "t,load0,load1,load2,load3,static_s,scheduled_s,decision\n",
    );
    let mut static_series = Vec::with_capacity(1000);
    let mut dyn_series = Vec::with_capacity(1000);
    let mut n_diffusions = 0usize;
    let mut n_replans = 0usize;
    for t in 0..trace.steps() {
        let loads: Vec<f64> = (0..n).map(|j| trace.at(t, j)).collect();
        let mut decision = "keep".to_string();
        // scheduler fires every 10 steps (metadata reporting period)
        if t % 10 == 9 {
            let models: Vec<PerfModel> =
                (0..n).map(|j| scaled(j, loads[j])).collect();
            let real_times =
                diffusion::estimate_times(&g, &dyn_assign, n, &models);
            match schedule(&g, &spec, &cluster, &opts, &mut dyn_assign,
                           &real_times, &models, &cfg) {
                SchedulerDecision::Keep => {}
                SchedulerDecision::Diffused(m) => {
                    n_diffusions += 1;
                    decision = format!("diffuse({m})");
                }
                SchedulerDecision::Replanned => {
                    n_replans += 1;
                    decision = "replan".into();
                }
            }
        }
        let ls = latency_of(&static_assign, &loads);
        let ld = latency_of(&dyn_assign, &loads);
        static_series.push(ls);
        dyn_series.push(ld);
        csv.push_str(&format!(
            "{t},{:.3},{:.3},{:.3},{:.3},{ls:.4},{ld:.4},{decision}\n",
            loads[0], loads[1], loads[2], loads[3]
        ));
    }
    let _ = std::fs::create_dir_all(&ctx.results_dir);
    let _ = std::fs::write(ctx.results_dir.join("fig16_trace.csv"), csv);
    let _ = static_assign; // static baseline never mutates

    let mx = |v: &[f64]| v.iter().cloned().fold(0f64, f64::max);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    // high-load window (ramp plateau)
    let hot = 350..700;
    let best_reduction = static_series
        .iter()
        .zip(&dyn_series)
        .map(|(s, d)| 1.0 - d / s)
        .fold(f64::MIN, f64::max);
    let mut t = Table::new(&["metric", "w/o scheduler", "with scheduler"]);
    t.row(vec!["peak latency (s)".into(), f3(mx(&static_series)),
               f3(mx(&dyn_series))]);
    t.row(vec![
        "mean latency, loaded phase (s)".into(),
        f3(mean(&static_series[hot.clone()])),
        f3(mean(&dyn_series[hot])),
    ]);
    t.row(vec![
        "mean latency, full trace (s)".into(),
        f3(mean(&static_series)),
        f3(mean(&dyn_series)),
    ]);
    format!(
        "## Fig. 16 — scheduler behaviour under the load trace (SIoT, GCN, \
         4 fogs)\n\n{}\n\
         decisions: {n_diffusions} diffusion adjustments, {n_replans} \
         global replans; max per-step latency reduction {} \
         (paper: up to 18.79%). Full series in results/fig16_trace.csv.\n",
        t.to_markdown(),
        pct(best_reduction)
    )
}
