//! §IV-C case study — traffic flow forecasting on the PeMS twin with
//! ASTGCN over the 4-node cluster (1A+2B+1C): Fig. 13 (placement map,
//! load distribution, latency, throughput) and Table V (forecasting
//! errors incl. the uniform-8-bit comparator).

use crate::compress::Codec;
use crate::fog::Cluster;
use crate::net::NetKind;
use crate::serving::accuracy::{average_errors, forecast_errors,
                               ForecastErrors};
use crate::serving::{Placement, ServeOpts};

use super::context::Ctx;
use super::tables::{f2, f3, speedup, Table};

const MODEL: &str = "astgcn";
const DATASET: &str = "pems";

fn sys_opts(g: &crate::graph::Graph, net: NetKind)
            -> Vec<(&'static str, Cluster, ServeOpts)> {
    vec![
        (
            "cloud",
            Cluster::cloud(net),
            ServeOpts {
                wan: true,
                ..ServeOpts::new(MODEL, Placement::SingleNode(0),
                                 Codec::None)
            },
        ),
        (
            "fog",
            Cluster::case_study(net),
            ServeOpts::new(MODEL, Placement::MetisRandom(4), Codec::None),
        ),
        (
            "fograph",
            Cluster::case_study(net),
            ServeOpts::new(MODEL, Placement::Iep, ServeOpts::co_codec(g)),
        ),
    ]
}

pub fn fig13(ctx: &mut Ctx) -> String {
    let mut out = String::from(
        "## Fig. 13 — PeMS case study (ASTGCN, 1A+2B+1C)\n\n",
    );
    // ---- (a) placement map + (b) load distribution -------------------------
    let g = ctx.graph(DATASET).clone();
    let spec = ctx.spec(DATASET);
    let cluster = Cluster::case_study(NetKind::Wifi);
    let opts = ServeOpts::new(MODEL, Placement::Iep,
                              ServeOpts::co_codec(&g));
    let omegas = ctx.omegas_for(MODEL, DATASET, cluster.len());
    let assignment = crate::serving::pipeline::place(
        &g, &cluster, &opts, &omegas, &spec,
    );
    // dump the (a) scatter to CSV for plotting
    if let Some(coords) = &g.coords {
        let mut csv = String::from("x,y,fog\n");
        for (v, c) in coords.iter().enumerate() {
            csv.push_str(&format!("{},{},{}\n", c[0], c[1], assignment[v]));
        }
        let _ = std::fs::create_dir_all(&ctx.results_dir);
        let _ = std::fs::write(ctx.results_dir.join("fig13_placement.csv"),
                               csv);
        out.push_str(
            "(a) sensor placement written to results/fig13_placement.csv \
             (x, y, assigned fog).\n",
        );
    }
    // locality statistic: fraction of edges internal to a partition
    let (mut internal, mut total) = (0usize, 0usize);
    for v in 0..g.num_vertices() {
        for &u in g.neighbors(v) {
            total += 1;
            if assignment[v] == assignment[u as usize] {
                internal += 1;
            }
        }
    }
    out.push_str(&format!(
        "placement locality: {:.1}% of edges are partition-internal.\n\n",
        internal as f64 / total as f64 * 100.0
    ));

    let r = ctx.run(DATASET, &cluster, &opts);
    let mut t = Table::new(&["fog", "type", "vertices", "exec (s)"]);
    for (j, node) in cluster.nodes.iter().enumerate() {
        t.row(vec![
            format!("{}", j + 1),
            node.node_type.name().into(),
            format!("{}", r.per_fog_vertices[j]),
            f3(r.per_fog_exec_s[j]),
        ]);
    }
    out.push_str("(b) load distribution under IEP:\n\n");
    out.push_str(&t.to_markdown());
    let emax = r.per_fog_exec_s.iter().cloned().fold(0.0, f64::max);
    let emin = r
        .per_fog_exec_s
        .iter()
        .cloned()
        .filter(|&x| x > 0.0)
        .fold(f64::MAX, f64::min);
    out.push_str(&format!(
        "\nexec-time imbalance {} (close to 1 = heterogeneity-aware \
         balance; the type-C fog holds the most vertices).\n\n",
        f2(emax / emin.max(1e-9))
    ));

    // ---- (c)/(d) latency + throughput --------------------------------------
    let mut lt = Table::new(&[
        "net", "system", "latency (s)", "throughput (inf/s)", "vs cloud",
        "vs fog",
    ]);
    for net in NetKind::all() {
        let mut totals = Vec::new();
        for (name, cluster, opts) in sys_opts(&g, net) {
            let r = ctx.run(DATASET, &cluster, &opts);
            totals.push((name, r.total_s, r.throughput));
        }
        let cloud_t = totals[0].1;
        let fog_t = totals[1].1;
        for (name, total, thr) in &totals {
            lt.row(vec![
                net.name().into(),
                (*name).into(),
                f3(*total),
                f2(*thr),
                speedup(cloud_t, *total),
                speedup(fog_t, *total),
            ]);
        }
    }
    out.push_str("(c)/(d) latency and throughput:\n\n");
    out.push_str(&lt.to_markdown());
    out.push_str(
        "\nPaper: Fograph up to 2.79x vs cloud, 1.43x vs fog on this case.\n",
    );
    out
}

pub fn table5(ctx: &mut Ctx) -> String {
    let g = ctx.graph(DATASET).clone();
    let spec = ctx.spec(DATASET);
    // query windows in the held-out tail of the series
    let t_total = g.duration;
    let starts: Vec<usize> = (0..8)
        .map(|k| t_total - 24 - 1 - k * 36)
        .collect();
    let systems: Vec<(&str, Codec)> = vec![
        ("Cloud", Codec::None),
        ("Fog", Codec::None),
        ("Fograph", ServeOpts::co_codec(&g)),
        ("Uni. 8-bit", Codec::Uniform(8)),
    ];
    let cluster = Cluster::case_study(NetKind::Wifi);
    let mut rows: Vec<(String, ForecastErrors, ForecastErrors)> = Vec::new();
    for (name, codec) in systems {
        let mut e15 = Vec::new();
        let mut e30 = Vec::new();
        for &start in &starts {
            let placement = if name == "Cloud" {
                Placement::SingleNode(0)
            } else {
                Placement::Iep
            };
            let mut opts = ServeOpts::new(MODEL, placement, codec.clone());
            opts.keep_outputs = true;
            opts.window_start = start;
            let r = if name == "Cloud" {
                let cc = Cluster::cloud(NetKind::Wifi);
                let mut o = opts.clone();
                o.wan = true;
                ctx.run(DATASET, &cc, &o)
            } else {
                ctx.run(DATASET, &cluster, &opts)
            };
            let outputs = r.outputs.as_ref().expect("outputs");
            e15.push(forecast_errors(&g, &spec, outputs, r.out_dim, start,
                                     3));
            e30.push(forecast_errors(&g, &spec, outputs, r.out_dim, start,
                                     6));
        }
        rows.push((name.to_string(), average_errors(&e15),
                   average_errors(&e30)));
    }
    let mut t = Table::new(&[
        "method", "15min MAE", "15min RMSE", "15min MAPE", "30min MAE",
        "30min RMSE", "30min MAPE",
    ]);
    for (name, e15, e30) in &rows {
        t.row(vec![
            name.clone(),
            f2(e15.mae),
            f2(e15.rmse),
            f2(e15.mape),
            f2(e30.mae),
            f2(e30.rmse),
            f2(e30.mape),
        ]);
    }
    format!(
        "## Table V — traffic flow forecasting errors (PeMS, ASTGCN)\n\n{}\n\
         Expected shape (paper): Cloud == Fog (full precision); Fograph \
         within ~0.1 of full precision; uniform 8-bit clearly worse.\n",
        t.to_markdown()
    )
}
