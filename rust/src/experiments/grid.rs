//! Fig. 11 (latency) + Fig. 12 (throughput) + Table IV (accuracy): the
//! main comparison grid — {GCN, GAT, GraphSAGE} × {SIoT, Yelp} ×
//! {4G, 5G, WiFi} × {cloud, straw-man fog, Fograph}. One sweep feeds all
//! three report sections.

use crate::compress::Codec;
use crate::fog::Cluster;
use crate::net::NetKind;
use crate::serving::accuracy::accuracy;
use crate::serving::{Placement, ServeOpts, ServingReport};

use super::context::Ctx;
use super::tables::{f2, f3, speedup, Table};

pub struct GridResults {
    pub fig11: String,
    pub fig12: String,
    pub table4: String,
}

fn systems(g: &crate::graph::Graph, model: &str, net: NetKind)
           -> Vec<(&'static str, Cluster, ServeOpts)> {
    vec![
        (
            "cloud",
            Cluster::cloud(net),
            ServeOpts {
                wan: true,
                ..ServeOpts::new(model, Placement::SingleNode(0),
                                 Codec::None)
            },
        ),
        (
            "fog",
            Cluster::testbed(net),
            ServeOpts::new(model, Placement::MetisRandom(4), Codec::None),
        ),
        (
            "fograph",
            Cluster::testbed(net),
            ServeOpts::new(model, Placement::Iep, ServeOpts::co_codec(g)),
        ),
    ]
}

pub fn run(ctx: &mut Ctx) -> GridResults {
    let mut lat = Table::new(&[
        "dataset", "net", "model", "cloud (s)", "fog (s)", "fograph (s)",
        "vs cloud", "vs fog",
    ]);
    let mut thr = Table::new(&[
        "dataset", "net", "model", "cloud (inf/s)", "fog (inf/s)",
        "fograph (inf/s)", "x cloud", "x fog",
    ]);
    let mut acc = Table::new(&[
        "dataset", "model", "cloud (%)", "fog (%)", "fograph (%)",
        "drop (pp)",
    ]);
    let mut best_speedup_cloud: f64 = 0.0;
    let mut best_thr_cloud: f64 = 0.0;

    for dataset in ["siot", "yelp"] {
        for model in ["gcn", "gat", "sage"] {
            // accuracy once per (dataset, model) on WiFi (net-independent)
            let mut accs = Vec::new();
            for net in NetKind::all() {
                let g = ctx.graph(dataset).clone();
                let mut reports: Vec<(&str, ServingReport)> = Vec::new();
                for (name, cluster, mut opts) in systems(&g, model, net) {
                    let want_acc = net == NetKind::Wifi;
                    opts.keep_outputs = want_acc;
                    let r = ctx.run(dataset, &cluster, &opts);
                    reports.push((name, r));
                }
                let (ct, ft, gt) = (
                    reports[0].1.total_s,
                    reports[1].1.total_s,
                    reports[2].1.total_s,
                );
                best_speedup_cloud = best_speedup_cloud.max(ct / gt);
                best_thr_cloud = best_thr_cloud
                    .max(reports[2].1.throughput / reports[0].1.throughput);
                lat.row(vec![
                    dataset.into(),
                    net.name().into(),
                    model.into(),
                    f3(ct),
                    f3(ft),
                    f3(gt),
                    speedup(ct, gt),
                    speedup(ft, gt),
                ]);
                thr.row(vec![
                    dataset.into(),
                    net.name().into(),
                    model.into(),
                    f2(reports[0].1.throughput),
                    f2(reports[1].1.throughput),
                    f2(reports[2].1.throughput),
                    f2(reports[2].1.throughput
                        / reports[0].1.throughput.max(1e-9)),
                    f2(reports[2].1.throughput
                        / reports[1].1.throughput.max(1e-9)),
                ]);
                if net == NetKind::Wifi {
                    let labels =
                        ctx.graph(dataset).labels.clone().unwrap();
                    for (_, r) in &reports {
                        let o = r.outputs.as_ref().expect("outputs kept");
                        accs.push(accuracy(o, r.out_dim, &labels) * 100.0);
                    }
                }
            }
            acc.row(vec![
                dataset.into(),
                model.into(),
                f2(accs[0]),
                f2(accs[1]),
                f2(accs[2]),
                f2(accs[0] - accs[2]),
            ]);
        }
    }

    let fig11 = format!(
        "## Fig. 11 — serving latency across models, datasets, networks\n\n\
         {}\nmax Fograph-vs-cloud speedup observed: {:.2}x \
         (paper: up to 5.39x; latency reduction up to 82.18%).\n",
        lat.to_markdown(),
        best_speedup_cloud
    );
    let fig12 = format!(
        "## Fig. 12 — serving throughput across models, datasets, networks\n\n\
         {}\nmax Fograph-vs-cloud throughput gain: {:.2}x \
         (paper: up to 6.84x, 2.31x vs fog).\n",
        thr.to_markdown(),
        best_thr_cloud
    );
    let table4 = format!(
        "## Table IV — inference accuracy (full precision vs Fograph DAQ)\n\n\
         cloud and fog serve full-precision features (identical\n\
         accuracy); Fograph applies degree-aware quantization.\n\n{}\n\
         Paper: Fograph drops <0.1 pp on both datasets.\n",
        acc.to_markdown()
    );
    GridResults { fig11, fig12, table4 }
}
