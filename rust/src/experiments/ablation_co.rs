//! Extension ablation (DESIGN.md §Perf / paper §III-D "tunable
//! configurations ... left for future work"): the communication
//! optimizer's design space — codec stages, DAQ interval schemes and
//! bitwidth ladders — measured on the real dataset twins.

use crate::compress::{self, quantize::IntervalScheme, Codec, DaqConfig,
                      DEFAULT_BITS};
use crate::graph::Graph;

use super::context::Ctx;
use super::tables::{f2, Table};

fn pack_stats(g: &Graph, codec: &Codec) -> (f64, usize) {
    let rows: Vec<&[f32]> =
        g.features.chunks_exact(g.feature_dim * g.duration.max(1)).collect();
    let degrees: Vec<u64> =
        g.degrees().iter().map(|&d| d as u64).collect();
    let p = compress::pack(&rows, &degrees, codec);
    (p.compression_ratio(), p.wire_bytes)
}

pub fn run(ctx: &mut Ctx) -> String {
    let mut out = String::from(
        "## CO ablation — codec stages, interval schemes, bit ladders\n\n\
         Compression ratio = wire bytes / raw f64 payload (lower is\n\
         better). The paper fixes ⟨64,32,16,8⟩ with distribution-derived\n\
         intervals and leaves the configuration space to future work —\n\
         this table explores it on the twins.\n\n",
    );
    let mut t = Table::new(&["dataset", "codec", "ratio", "wire (MB)"]);
    for ds in ["siot", "yelp"] {
        let g = ctx.graph(ds).clone();
        let degrees = g.degrees();
        let mass = DaqConfig::from_degrees(&degrees,
                                           IntervalScheme::EqualMass,
                                           DEFAULT_BITS);
        let width = DaqConfig::from_degrees(&degrees,
                                            IntervalScheme::EqualWidth,
                                            DEFAULT_BITS);
        let aggressive = DaqConfig::from_degrees(&degrees,
                                                 IntervalScheme::EqualMass,
                                                 [32, 16, 8, 8]);
        let cases: Vec<(String, Codec)> = vec![
            ("raw f64".into(), Codec::None),
            ("LZ4 only".into(), Codec::Lz4Only),
            ("uniform 16-bit + LZ4".into(), Codec::Uniform(16)),
            ("uniform 8-bit + LZ4".into(), Codec::Uniform(8)),
            ("DAQ ⟨64,32,16,8⟩ equal-mass (paper)".into(),
             Codec::Daq(mass)),
            ("DAQ ⟨64,32,16,8⟩ equal-width".into(), Codec::Daq(width)),
            ("DAQ ⟨32,16,8,8⟩ equal-mass".into(), Codec::Daq(aggressive)),
        ];
        for (name, codec) in cases {
            let (ratio, wire) = pack_stats(&g, &codec);
            t.row(vec![
                ds.into(),
                name,
                format!("{ratio:.4}"),
                f2(wire as f64 / 1e6),
            ]);
        }
        // general-purpose comparators on the raw payload
        let raw: Vec<u8> = g
            .features
            .iter()
            .flat_map(|&x| (x as f64).to_le_bytes())
            .collect();
        let d = compress::pipeline::deflate_size(&raw);
        let z = compress::pipeline::zstd_size(&raw);
        let [dl, zl] = compress::pipeline::COMPARATOR_LABELS;
        t.row(vec![ds.into(), dl.into(),
                   format!("{:.4}", d as f64 / raw.len() as f64),
                   f2(d as f64 / 1e6)]);
        t.row(vec![ds.into(), zl.into(),
                   format!("{:.4}", z as f64 / raw.len() as f64),
                   f2(z as f64 / 1e6)]);
    }
    out.push_str(&t.to_markdown());
    out.push_str(
        "\nReading: LZ4-only leaves precision on the table; uniform-8\n\
         compresses hardest but costs accuracy (Table IV/V); the paper's\n\
         degree-aware ladder sits between, and equal-mass intervals beat\n\
         equal-width on power-law degree distributions (most vertices\n\
         would otherwise land in the widest full-precision band).\n",
    );
    out
}
