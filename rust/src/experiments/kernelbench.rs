//! `repro bench-kernels` — the kernel-perf baseline recorder: measures
//! the tiled GEMM and blocked SpMM against their in-tree naive
//! baselines at serving-relevant shapes (n ≥ 1024, f ∈ {64, 128, 256}),
//! plus batched-vs-serial fog execution on the persistent worker pool,
//! and writes BENCH_kernels.json so the repo's perf trajectory is
//! recorded run over run.
//!
//! `--smoke` runs a fast subset for CI; in every mode the tiled
//! kernels are parity-checked against the naive ones (1e-5 relative)
//! and a mismatch fails the command — the benchmark doubles as the
//! cross-kernel correctness gate at bench shapes.

use std::sync::Arc;

use crate::exec::BatchedBspPlan;
use crate::graph::{generate, subgraph};
use crate::runtime::csr_backend::CsrPartition;
use crate::runtime::kernels::{gemm, spmm};
use crate::runtime::{pad, Engine, EngineKind};
use crate::util::cli::Args;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Rng;
use crate::util::timer::{bench, black_box};

/// Relative parity tolerance between tiled and naive kernels.
const PARITY_TOL: f32 = 1e-5;

fn max_rel_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / (1.0 + x.abs().max(y.abs())))
        .fold(0f32, f32::max)
}

pub fn cmd(args: &Args) -> i32 {
    let smoke = args.has("smoke");
    let out_path = args.get_or("out", "BENCH_kernels.json");
    // smoke keeps CI turnaround low; full runs settle the timings
    let min_s = if smoke { 0.08 } else { 0.5 };
    println!(
        "== kernel bench ({}) ==",
        if smoke { "smoke" } else { "full" }
    );

    // ---- GEMM: tiled vs naive ------------------------------------------
    let gemm_shapes: &[(usize, usize, usize)] = if smoke {
        &[(1024, 64, 64), (1024, 128, 128), (1024, 256, 256)]
    } else {
        &[
            (1024, 64, 64),
            (1024, 128, 128),
            (1024, 256, 256),
            (2048, 128, 64),
            (4096, 64, 64),
        ]
    };
    let mut gemm_rows: Vec<Json> = Vec::new();
    let mut min_gemm_speedup = f64::INFINITY;
    for &(n, fi, fo) in gemm_shapes {
        let mut rng = Rng::new(0x6E66 ^ (n * fi * fo) as u64);
        let x: Vec<f32> =
            (0..n * fi).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let tiled = gemm::gemm_bias(&x, n, fi, &w, fo, &b);
        let naive = gemm::gemm_bias_naive(&x, n, fi, &w, fo, &b);
        let err = max_rel_diff(&tiled, &naive);
        if err > PARITY_TOL {
            eprintln!(
                "PARITY FAIL gemm {n}x{fi}x{fo}: tiled deviates from \
                 naive by {err}"
            );
            return 1;
        }
        let rn = bench(&format!("gemm/naive_{n}x{fi}x{fo}"), min_s,
                       10_000, || {
            black_box(gemm::gemm_bias_naive(&x, n, fi, &w, fo, &b));
        });
        let rt = bench(&format!("gemm/tiled_{n}x{fi}x{fo}"), min_s,
                       10_000, || {
            black_box(gemm::gemm_bias(&x, n, fi, &w, fo, &b));
        });
        let flop = 2.0 * (n * fi * fo) as f64;
        let speedup = rn.p50_ns / rt.p50_ns;
        min_gemm_speedup = min_gemm_speedup.min(speedup);
        println!(
            "gemm {n:>5}x{fi:>3}x{fo:>3}  naive {:>8.2} ms  tiled \
             {:>8.2} ms  {:>5.2}x  {:>6.2} GFLOP/s",
            rn.p50_ns / 1e6,
            rt.p50_ns / 1e6,
            speedup,
            flop / rt.p50_ns
        );
        gemm_rows.push(obj(vec![
            ("n", num(n as f64)),
            ("f_in", num(fi as f64)),
            ("f_out", num(fo as f64)),
            ("naive_ms", num(rn.p50_ns / 1e6)),
            ("tiled_ms", num(rt.p50_ns / 1e6)),
            ("speedup", num(speedup)),
            ("gflops_naive", num(flop / rn.p50_ns)),
            ("gflops_tiled", num(flop / rt.p50_ns)),
            ("max_rel_err", num(err as f64)),
        ]));
    }

    // ---- SpMM: blocked vs naive ----------------------------------------
    let (nv, ne) = if smoke { (4096, 32_768) } else { (16_384, 131_072) };
    let (g, _) = generate::sbm(nv, ne, 16, 0.8, 7);
    let all_on_one = vec![0u32; nv];
    let (subs, _) = subgraph::extract(&g, &all_on_one, 1);
    let edges = pad::prep_edges("gcn", &subs[0]).unwrap();
    let csr = CsrPartition::from_edges(&edges);
    let nnz = csr.num_edges();
    let mut spmm_rows: Vec<Json> = Vec::new();
    let mut min_spmm_speedup = f64::INFINITY;
    for &f in &[64usize, 128, 256] {
        let mut rng = Rng::new(0x5B33 ^ f as u64);
        let h: Vec<f32> =
            (0..csr.n * f).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let blocked = spmm::csr_spmm(&csr, &h, f);
        let naive = spmm::csr_spmm_naive(&csr, &h, f);
        let err = max_rel_diff(&blocked, &naive);
        if err > PARITY_TOL {
            eprintln!(
                "PARITY FAIL spmm v={nv} f={f}: blocked deviates from \
                 naive by {err}"
            );
            return 1;
        }
        let rn = bench(&format!("spmm/naive_v{nv}_f{f}"), min_s,
                       10_000, || {
            black_box(spmm::csr_spmm_naive(&csr, &h, f));
        });
        let rt = bench(&format!("spmm/blocked_v{nv}_f{f}"), min_s,
                       10_000, || {
            black_box(spmm::csr_spmm(&csr, &h, f));
        });
        // effective traffic: gathered rows + written aggregate + CSR
        // metadata (col u32 + val f32 + amortized row_ptr)
        let bytes = ((nnz + csr.n_local) * f * 4 + nnz * 12) as f64;
        let speedup = rn.p50_ns / rt.p50_ns;
        min_spmm_speedup = min_spmm_speedup.min(speedup);
        println!(
            "spmm v={nv} nnz={nnz} f={f:>3}  naive {:>8.2} ms  blocked \
             {:>8.2} ms  {:>5.2}x  {:>6.2} GB/s",
            rn.p50_ns / 1e6,
            rt.p50_ns / 1e6,
            speedup,
            bytes / rt.p50_ns
        );
        spmm_rows.push(obj(vec![
            ("vertices", num(nv as f64)),
            ("nnz", num(nnz as f64)),
            ("f", num(f as f64)),
            ("naive_ms", num(rn.p50_ns / 1e6)),
            ("blocked_ms", num(rt.p50_ns / 1e6)),
            ("speedup", num(speedup)),
            ("gbps_naive", num(bytes / rn.p50_ns)),
            ("gbps_blocked", num(bytes / rt.p50_ns)),
            ("max_rel_err", num(err as f64)),
        ]));
    }

    // ---- fog exec: batched pool vs serial per-request -------------------
    let (fnv, fne) = if smoke { (2048, 16_384) } else { (8192, 65_536) };
    let (mut fg, _) = generate::sbm(fnv, fne, 8, 0.82, 11);
    let f_in = 64;
    let mut rng = Rng::new(0xF06E);
    fg.feature_dim = f_in;
    fg.features =
        (0..fnv * f_in).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let assignment: Vec<u32> =
        (0..fnv).map(|v| (v % 4) as u32).collect();
    let dir = std::env::temp_dir().join("bench_kernels");
    std::fs::create_dir_all(&dir).unwrap();
    let mut engine = Engine::new(EngineKind::Csr, &dir).unwrap();
    let wb = Arc::new(
        engine.weights("gcn", "benchkernels", f_in, 8).clone(),
    );
    let plan = BatchedBspPlan::new(&fg, &assignment, 4, "gcn").unwrap();
    let batch = 8;
    // pooled and serial execution must agree bit-for-bit
    let pooled = plan.execute(&fg.features, f_in, &wb, batch);
    let serial = plan.execute_serial(&fg.features, f_in, &wb, batch);
    if pooled.outputs != serial.outputs {
        eprintln!("PARITY FAIL fog exec: pooled != serial outputs");
        return 1;
    }
    let rb = bench("exec/pool_batched_b8_4fogs", min_s.max(0.2),
                   10_000, || {
        black_box(plan.execute_timings(&fg.features, f_in, &wb, batch));
    });
    let rs = bench("exec/pool_serial_8x_b1_4fogs", min_s.max(0.2),
                   10_000, || {
        for _ in 0..batch {
            black_box(plan.execute_timings(&fg.features, f_in, &wb, 1));
        }
    });
    let fog_speedup = rs.p50_ns / rb.p50_ns;
    println!(
        "fog exec v={fnv} b={batch}  serial {:>8.2} ms  batched \
         {:>8.2} ms  {:>5.2}x",
        rs.p50_ns / 1e6,
        rb.p50_ns / 1e6,
        fog_speedup
    );
    let fog_rows = vec![obj(vec![
        ("vertices", num(fnv as f64)),
        ("fogs", num(4.0)),
        ("batch", num(batch as f64)),
        ("model", s("gcn")),
        ("serial_ms", num(rs.p50_ns / 1e6)),
        ("batched_ms", num(rb.p50_ns / 1e6)),
        ("speedup", num(fog_speedup)),
    ])];

    println!(
        "min speedups: gemm {min_gemm_speedup:.2}x, spmm \
         {min_spmm_speedup:.2}x (parity ok at {PARITY_TOL} rel)"
    );

    let doc = obj(vec![
        ("benchmark", s("kernels")),
        ("generated_by", s("repro bench-kernels")),
        // all _ms / throughput / speedup values are p50-of-samples
        // (robust on noisy shared hosts)
        ("stat", s("p50")),
        ("smoke", Json::Bool(smoke)),
        ("gemm", arr(gemm_rows)),
        ("spmm", arr(spmm_rows)),
        ("fog_exec", arr(fog_rows)),
        (
            "summary",
            obj(vec![
                ("min_gemm_speedup", num(min_gemm_speedup)),
                ("min_spmm_speedup", num(min_spmm_speedup)),
                ("fog_batched_speedup", num(fog_speedup)),
                ("parity_tol_rel", num(PARITY_TOL as f64)),
            ]),
        ),
    ]);
    match std::fs::write(out_path, format!("{doc}\n")) {
        Ok(()) => {
            println!("wrote {out_path}");
            0
        }
        Err(e) => {
            eprintln!("cannot write {out_path}: {e}");
            1
        }
    }
}
