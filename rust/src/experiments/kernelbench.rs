//! `repro bench-kernels` — the kernel-perf baseline recorder: measures
//! the tiled GEMM and blocked SpMM against their in-tree naive
//! baselines at serving-relevant shapes (n ≥ 1024, f ∈ {64, 128, 256}),
//! the intra-fog thread-scaling curve (1/2/4-worker row sharding on
//! the largest single-fog shapes), the dispatched-vs-scalar SIMD
//! margin when the AVX2+FMA path is active, plus batched-vs-serial fog
//! execution on the persistent worker pool and the flight-recorder
//! overhead gate (`recorder_overhead`: traced vs untraced kernel loop,
//! enabled tracing must stay under 2%), and writes
//! BENCH_kernels.json so the repo's perf trajectory is recorded run
//! over run. Every run also appends a one-line summary (date, git rev,
//! stat, per-shape speedups, SIMD path, thread scaling) to
//! BENCH_history.jsonl, so regressions are visible ACROSS runs, not
//! just within one artifact.
//!
//! `--smoke` runs a fast subset for CI; `--kernel-threads` caps the
//! scaling curve. In every mode the tiled kernels are parity-checked
//! against the naive ones (1e-5 relative), sharded results are
//! asserted bitwise-equal to unsharded ones, and pooled / sharded /
//! serial BSP outputs are asserted bit-identical — a mismatch fails
//! the command, so the benchmark doubles as the cross-kernel
//! correctness gate at bench shapes.

use std::io::Write;
use std::sync::Arc;

use crate::exec::BatchedBspPlan;
use crate::graph::{generate, subgraph};
use crate::obs::clock::ClockMode;
use crate::obs::recorder::{Recorder, Ring};
use crate::obs::span::{Phase, SpanEvent};
use crate::runtime::csr_backend::CsrPartition;
use crate::runtime::kernels::shard::{min_rows_per_shard,
                                     min_rows_per_shard_source,
                                     split_rows,
                                     ShardClosure, ShardExec,
                                     ShardGroup};
use crate::runtime::kernels::{gemm, simd, spmm};
use crate::runtime::{pad, Engine, EngineKind};
use crate::util::cli::{parse_kernel_threads, Args};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::provenance::{git_rev, peak_rss_bytes,
                              utc_date_string};
use crate::util::rng::Rng;
use crate::util::timer::{bench, black_box};

/// Relative parity tolerance between tiled and naive kernels.
const PARITY_TOL: f32 = 1e-5;

/// Enabled-tracing overhead gate on the serving-shaped kernel loop:
/// the flight recorder must stay under this relative cost (see
/// `obs::recorder`'s design constraints).
const RECORDER_GATE_PCT: f64 = 2.0;

/// `num`, except non-finite (curve skipped) becomes JSON null.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        num(x)
    } else {
        Json::Null
    }
}

fn max_rel_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs() / (1.0 + x.abs().max(y.abs())))
        .fold(0f32, f32::max)
}

/// Row-sharded GEMM on an executor: the bench-side mirror of what a
/// fog leader does for a large `FogJob` (split, run, ordered concat).
fn gemm_sharded(exec: &ShardExec<'_>, x: &Arc<Vec<f32>>, n: usize,
                fi: usize, w: &Arc<Vec<f32>>, fo: usize,
                b: &Arc<Vec<f32>>) -> Vec<f32> {
    let ranges = split_rows(n, exec.effective_shards(n));
    let closures: Vec<ShardClosure> = ranges
        .iter()
        .map(|&(r0, r1)| {
            let (x, w, b) = (x.clone(), w.clone(), b.clone());
            Box::new(move || {
                gemm::gemm_bias_rows(&x, fi, &w, fo, &b, r0, r1)
            }) as ShardClosure
        })
        .collect();
    let mut out = Vec::with_capacity(n * fo);
    for sh in exec.run(closures) {
        out.extend_from_slice(&sh);
    }
    out
}

/// Row-sharded SpMM on an executor (owned-row ranges, ordered concat).
fn spmm_sharded(exec: &ShardExec<'_>, csr: &Arc<CsrPartition>,
                h: &Arc<Vec<f32>>, f: usize) -> Vec<f32> {
    let ranges =
        split_rows(csr.n_local, exec.effective_shards(csr.n_local));
    let closures: Vec<ShardClosure> = ranges
        .iter()
        .map(|&(v0, v1)| {
            let (csr, h) = (csr.clone(), h.clone());
            Box::new(move || spmm::csr_spmm_rows(&csr, &h, f, v0, v1))
                as ShardClosure
        })
        .collect();
    let mut out = Vec::with_capacity(csr.n_local * f);
    for sh in exec.run(closures) {
        out.extend_from_slice(&sh);
    }
    out
}

pub fn cmd(args: &Args) -> i32 {
    let smoke = args.has("smoke");
    let out_path = args.get_or("out", "BENCH_kernels.json");
    let history_path = args.get_or("history", "BENCH_history.jsonl");
    // scaling-curve cap: 1/2/4 workers by default
    let max_threads = match parse_kernel_threads(args) {
        Ok(1) => {
            if args.get("kernel-threads").is_some() {
                1 // explicit --kernel-threads 1: skip the curve
            } else {
                4
            }
        }
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // smoke keeps CI turnaround low; full runs settle the timings
    let min_s = if smoke { 0.08 } else { 0.5 };
    // the active shard floor (FOGRAPH_MIN_ROWS_PER_SHARD override, or
    // the one-shot micro-probe value); main() has already rejected
    // invalid override values
    let min_rows = min_rows_per_shard();
    let min_rows_source = min_rows_per_shard_source();
    println!(
        "== kernel bench ({}, simd={}, kernel-threads<={max_threads}, \
         min-rows-per-shard={min_rows} [{min_rows_source}]) ==",
        if smoke { "smoke" } else { "full" },
        simd::name()
    );

    // ---- GEMM: tiled vs naive ------------------------------------------
    let gemm_shapes: &[(usize, usize, usize)] = if smoke {
        &[(1024, 64, 64), (1024, 128, 128), (1024, 256, 256)]
    } else {
        &[
            (1024, 64, 64),
            (1024, 128, 128),
            (1024, 256, 256),
            (2048, 128, 64),
            (4096, 64, 64),
        ]
    };
    let mut gemm_rows: Vec<Json> = Vec::new();
    let mut gemm_speedups: Vec<(String, f64)> = Vec::new();
    let mut min_gemm_speedup = f64::INFINITY;
    for &(n, fi, fo) in gemm_shapes {
        let mut rng = Rng::new(0x6E66 ^ (n * fi * fo) as u64);
        let x: Vec<f32> =
            (0..n * fi).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let tiled = gemm::gemm_bias(&x, n, fi, &w, fo, &b);
        let naive = gemm::gemm_bias_naive(&x, n, fi, &w, fo, &b);
        let err = max_rel_diff(&tiled, &naive);
        if err > PARITY_TOL {
            eprintln!(
                "PARITY FAIL gemm {n}x{fi}x{fo}: tiled deviates from \
                 naive by {err}"
            );
            return 1;
        }
        let rn = bench(&format!("gemm/naive_{n}x{fi}x{fo}"), min_s,
                       10_000, || {
            black_box(gemm::gemm_bias_naive(&x, n, fi, &w, fo, &b));
        });
        let rt = bench(&format!("gemm/tiled_{n}x{fi}x{fo}"), min_s,
                       10_000, || {
            black_box(gemm::gemm_bias(&x, n, fi, &w, fo, &b));
        });
        let flop = 2.0 * (n * fi * fo) as f64;
        let speedup = rn.p50_ns / rt.p50_ns;
        min_gemm_speedup = min_gemm_speedup.min(speedup);
        println!(
            "gemm {n:>5}x{fi:>3}x{fo:>3}  naive {:>8.2} ms  tiled \
             {:>8.2} ms  {:>5.2}x  {:>6.2} GFLOP/s",
            rn.p50_ns / 1e6,
            rt.p50_ns / 1e6,
            speedup,
            flop / rt.p50_ns
        );
        gemm_speedups.push((format!("{n}x{fi}x{fo}"), speedup));
        gemm_rows.push(obj(vec![
            ("n", num(n as f64)),
            ("f_in", num(fi as f64)),
            ("f_out", num(fo as f64)),
            ("naive_ms", num(rn.p50_ns / 1e6)),
            ("tiled_ms", num(rt.p50_ns / 1e6)),
            ("speedup", num(speedup)),
            ("gflops_naive", num(flop / rn.p50_ns)),
            ("gflops_tiled", num(flop / rt.p50_ns)),
            ("max_rel_err", num(err as f64)),
        ]));
    }

    // ---- SpMM: blocked vs naive ----------------------------------------
    let (nv, ne) = if smoke { (4096, 32_768) } else { (16_384, 131_072) };
    let (g, _) = generate::sbm(nv, ne, 16, 0.8, 7);
    let all_on_one = vec![0u32; nv];
    let (subs, _) = subgraph::extract(&g, &all_on_one, 1);
    let edges = pad::prep_edges("gcn", &subs[0]).unwrap();
    let csr = Arc::new(CsrPartition::from_edges(&edges));
    let nnz = csr.num_edges();
    let mut spmm_rows: Vec<Json> = Vec::new();
    let mut spmm_speedups: Vec<(String, f64)> = Vec::new();
    let mut min_spmm_speedup = f64::INFINITY;
    for &f in &[64usize, 128, 256] {
        let mut rng = Rng::new(0x5B33 ^ f as u64);
        let h: Vec<f32> =
            (0..csr.n * f).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let blocked = spmm::csr_spmm(&csr, &h, f);
        let naive = spmm::csr_spmm_naive(&csr, &h, f);
        let err = max_rel_diff(&blocked, &naive);
        if err > PARITY_TOL {
            eprintln!(
                "PARITY FAIL spmm v={nv} f={f}: blocked deviates from \
                 naive by {err}"
            );
            return 1;
        }
        let rn = bench(&format!("spmm/naive_v{nv}_f{f}"), min_s,
                       10_000, || {
            black_box(spmm::csr_spmm_naive(&csr, &h, f));
        });
        let rt = bench(&format!("spmm/blocked_v{nv}_f{f}"), min_s,
                       10_000, || {
            black_box(spmm::csr_spmm(&csr, &h, f));
        });
        // effective traffic: gathered rows + written aggregate + CSR
        // metadata (col u32 + val f32 + amortized row_ptr)
        let bytes = ((nnz + csr.n_local) * f * 4 + nnz * 12) as f64;
        let speedup = rn.p50_ns / rt.p50_ns;
        min_spmm_speedup = min_spmm_speedup.min(speedup);
        println!(
            "spmm v={nv} nnz={nnz} f={f:>3}  naive {:>8.2} ms  blocked \
             {:>8.2} ms  {:>5.2}x  {:>6.2} GB/s",
            rn.p50_ns / 1e6,
            rt.p50_ns / 1e6,
            speedup,
            bytes / rt.p50_ns
        );
        spmm_speedups.push((format!("v{nv}_f{f}"), speedup));
        spmm_rows.push(obj(vec![
            ("vertices", num(nv as f64)),
            ("nnz", num(nnz as f64)),
            ("f", num(f as f64)),
            ("naive_ms", num(rn.p50_ns / 1e6)),
            ("blocked_ms", num(rt.p50_ns / 1e6)),
            ("speedup", num(speedup)),
            ("gbps_naive", num(bytes / rn.p50_ns)),
            ("gbps_blocked", num(bytes / rt.p50_ns)),
            ("max_rel_err", num(err as f64)),
        ]));
    }

    // ---- SIMD margin: dispatched path vs portable scalar ----------------
    // Only meaningful when the dispatcher picked AVX2+FMA; the margin
    // doubles as the avx2-vs-scalar parity gate at bench shapes.
    let mut simd_rows: Vec<Json> = Vec::new();
    if simd::avx2_active() {
        let (n, fi, fo) =
            if smoke { (1024, 128, 128) } else { (1024, 256, 256) };
        let mut rng = Rng::new(0x51D1);
        let x: Vec<f32> =
            (0..n * fi).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let dispatched = gemm::gemm_bias(&x, n, fi, &w, fo, &b);
        let mut scalar = vec![0f32; n * fo];
        gemm::gemm_bias_into_scalar(&x, n, fi, &w, fo, &b,
                                    &mut scalar);
        let err = max_rel_diff(&dispatched, &scalar);
        if err > PARITY_TOL {
            eprintln!(
                "PARITY FAIL simd gemm {n}x{fi}x{fo}: avx2 deviates \
                 from scalar by {err}"
            );
            return 1;
        }
        let ra = bench(&format!("gemm/avx2_{n}x{fi}x{fo}"), min_s,
                       10_000, || {
            black_box(gemm::gemm_bias(&x, n, fi, &w, fo, &b));
        });
        let rs = bench(&format!("gemm/scalar_{n}x{fi}x{fo}"), min_s,
                       10_000, || {
            let mut out = vec![0f32; n * fo];
            gemm::gemm_bias_into_scalar(&x, n, fi, &w, fo, &b,
                                        &mut out);
            black_box(out);
        });
        let margin = rs.p50_ns / ra.p50_ns;
        println!(
            "simd gemm {n}x{fi}x{fo}  scalar {:>8.2} ms  avx2+fma \
             {:>8.2} ms  {:>5.2}x",
            rs.p50_ns / 1e6,
            ra.p50_ns / 1e6,
            margin
        );
        simd_rows.push(obj(vec![
            ("kernel", s("gemm")),
            ("n", num(n as f64)),
            ("f_in", num(fi as f64)),
            ("f_out", num(fo as f64)),
            ("scalar_ms", num(rs.p50_ns / 1e6)),
            ("simd_ms", num(ra.p50_ns / 1e6)),
            ("speedup", num(margin)),
            ("max_rel_err", num(err as f64)),
        ]));
        // SpMM: the AVX2 kernel is NOT dispatched (measured even, see
        // the spmm.rs design note) — this row keeps that measurement
        // honest run over run.
        let f = if smoke { 64 } else { 256 };
        let h: Vec<f32> =
            (0..csr.n * f).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let scalar = spmm::csr_spmm(&csr, &h, f);
        let mut avx2 = vec![0f32; csr.n_local * f];
        assert!(simd::try_csr_spmm_rows_into(&csr, &h, f, 0,
                                             csr.n_local, &mut avx2));
        let err = max_rel_diff(&avx2, &scalar);
        if err > PARITY_TOL {
            eprintln!(
                "PARITY FAIL simd spmm v={nv} f={f}: avx2 deviates \
                 from scalar by {err}"
            );
            return 1;
        }
        let ra = bench(&format!("spmm/avx2_v{nv}_f{f}"), min_s,
                       10_000, || {
            let mut out = vec![0f32; csr.n_local * f];
            simd::try_csr_spmm_rows_into(&csr, &h, f, 0, csr.n_local,
                                         &mut out);
            black_box(out);
        });
        let rs = bench(&format!("spmm/scalar_v{nv}_f{f}"), min_s,
                       10_000, || {
            black_box(spmm::csr_spmm(&csr, &h, f));
        });
        let margin = rs.p50_ns / ra.p50_ns;
        println!(
            "simd spmm v={nv} f={f}  scalar {:>8.2} ms  avx2+fma \
             {:>8.2} ms  {:>5.2}x (not dispatched; see spmm.rs)",
            rs.p50_ns / 1e6,
            ra.p50_ns / 1e6,
            margin
        );
        simd_rows.push(obj(vec![
            ("kernel", s("spmm")),
            ("vertices", num(nv as f64)),
            ("f", num(f as f64)),
            ("scalar_ms", num(rs.p50_ns / 1e6)),
            ("simd_ms", num(ra.p50_ns / 1e6)),
            ("speedup", num(margin)),
            ("max_rel_err", num(err as f64)),
        ]));
    } else {
        println!("simd margin: skipped ({})", simd::name());
    }

    // ---- intra-fog thread scaling (row-sharded kernels) -----------------
    // The largest single-fog shapes: precisely the case where one fog
    // used to run serial while other cores idled. The curve doubles
    // worker counts and always ends at exactly --kernel-threads, so
    // `scaling_at_max_workers` in the artifact/history line is
    // measured at the width the run is labeled with.
    let workers: Vec<usize> = {
        let mut ws = vec![1usize];
        let mut w = 2;
        while w < max_threads {
            ws.push(w);
            w *= 2;
        }
        if max_threads > 1 {
            ws.push(max_threads);
        }
        ws
    };
    let mut scaling_rows: Vec<Json> = Vec::new();
    let mut gemm_scaling_max = f64::NAN;
    let mut spmm_scaling_max = f64::NAN;
    if workers.len() > 1 {
        let scale_gemm: &[(usize, usize, usize)] = if smoke {
            &[(1024, 128, 128)]
        } else {
            &[(1024, 256, 256), (4096, 64, 64)]
        };
        for &(n, fi, fo) in scale_gemm {
            let mut rng = Rng::new(0x7C41 ^ (n * fi) as u64);
            let x: Arc<Vec<f32>> = Arc::new(
                (0..n * fi).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
            );
            let w: Arc<Vec<f32>> = Arc::new(
                (0..fi * fo)
                    .map(|_| rng.normal_f32(0.0, 0.3))
                    .collect(),
            );
            let b: Arc<Vec<f32>> = Arc::new(
                (0..fo).map(|_| rng.normal_f32(0.0, 0.3)).collect(),
            );
            let reference = gemm::gemm_bias(&x, n, fi, &w, fo, &b);
            let mut t1 = f64::NAN;
            for &wk in &workers {
                let group = ShardGroup::new(wk - 1, "bench");
                let exec = ShardExec::Group(&group);
                let sharded =
                    gemm_sharded(&exec, &x, n, fi, &w, fo, &b);
                if sharded != reference {
                    eprintln!(
                        "PARITY FAIL gemm {n}x{fi}x{fo} w{wk}: \
                         sharded != unsharded (bitwise)"
                    );
                    return 1;
                }
                let r = bench(
                    &format!("gemm/sharded_{n}x{fi}x{fo}_w{wk}"),
                    min_s,
                    10_000,
                    || {
                        black_box(gemm_sharded(&exec, &x, n, fi, &w,
                                               fo, &b));
                    },
                );
                if wk == 1 {
                    t1 = r.p50_ns;
                }
                let sp = t1 / r.p50_ns;
                if wk == *workers.last().unwrap() {
                    gemm_scaling_max = if gemm_scaling_max.is_nan() {
                        sp
                    } else {
                        gemm_scaling_max.min(sp)
                    };
                }
                println!(
                    "scaling gemm {n:>5}x{fi:>3}x{fo:>3}  w{wk}  \
                     {:>8.2} ms  {sp:>5.2}x vs w1",
                    r.p50_ns / 1e6
                );
                scaling_rows.push(obj(vec![
                    ("kernel", s("gemm")),
                    ("n", num(n as f64)),
                    ("f_in", num(fi as f64)),
                    ("f_out", num(fo as f64)),
                    ("workers", num(wk as f64)),
                    ("ms", num(r.p50_ns / 1e6)),
                    ("speedup_vs_1", num(sp)),
                ]));
            }
        }
        let scale_f = if smoke { 64usize } else { 256 };
        let mut rng = Rng::new(0x7C42);
        let h: Arc<Vec<f32>> = Arc::new(
            (0..csr.n * scale_f)
                .map(|_| rng.normal_f32(0.0, 0.5))
                .collect(),
        );
        let reference = spmm::csr_spmm(&csr, &h, scale_f);
        let mut t1 = f64::NAN;
        for &wk in &workers {
            let group = ShardGroup::new(wk - 1, "bench");
            let exec = ShardExec::Group(&group);
            let sharded = spmm_sharded(&exec, &csr, &h, scale_f);
            if sharded != reference {
                eprintln!(
                    "PARITY FAIL spmm v={nv} f={scale_f} w{wk}: \
                     sharded != unsharded (bitwise)"
                );
                return 1;
            }
            let r = bench(
                &format!("spmm/sharded_v{nv}_f{scale_f}_w{wk}"),
                min_s,
                10_000,
                || {
                    black_box(spmm_sharded(&exec, &csr, &h, scale_f));
                },
            );
            if wk == 1 {
                t1 = r.p50_ns;
            }
            let sp = t1 / r.p50_ns;
            if wk == *workers.last().unwrap() {
                // same worst-case min-fold as the gemm loop, so adding
                // a second SpMM shape cannot silently over-report
                spmm_scaling_max = if spmm_scaling_max.is_nan() {
                    sp
                } else {
                    spmm_scaling_max.min(sp)
                };
            }
            println!(
                "scaling spmm v={nv} f={scale_f}  w{wk}  {:>8.2} ms  \
                 {sp:>5.2}x vs w1",
                r.p50_ns / 1e6
            );
            scaling_rows.push(obj(vec![
                ("kernel", s("spmm")),
                ("vertices", num(nv as f64)),
                ("f", num(scale_f as f64)),
                ("workers", num(wk as f64)),
                ("ms", num(r.p50_ns / 1e6)),
                ("speedup_vs_1", num(sp)),
            ]));
        }
    } else {
        println!("thread scaling: skipped (--kernel-threads 1)");
    }

    // ---- fog exec: batched pool vs serial per-request -------------------
    let (fnv, fne) = if smoke { (2048, 16_384) } else { (8192, 65_536) };
    let (mut fg, _) = generate::sbm(fnv, fne, 8, 0.82, 11);
    let f_in = 64;
    let mut rng = Rng::new(0xF06E);
    fg.feature_dim = f_in;
    fg.features =
        (0..fnv * f_in).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let assignment: Vec<u32> =
        (0..fnv).map(|v| (v % 4) as u32).collect();
    let dir = std::env::temp_dir().join("bench_kernels");
    std::fs::create_dir_all(&dir).unwrap();
    let mut engine = Engine::new(EngineKind::Csr, &dir).unwrap();
    let wb = Arc::new(
        engine.weights("gcn", "benchkernels", f_in, 8).clone(),
    );
    let plan = BatchedBspPlan::new(&fg, &assignment, 4, "gcn").unwrap();
    let batch = 8;
    // pooled, serial and intra-fog-sharded execution must agree
    // bit-for-bit
    let pooled = plan.execute(&fg.features, f_in, &wb, batch);
    let serial = plan.execute_serial(&fg.features, f_in, &wb, batch);
    if pooled.outputs != serial.outputs {
        eprintln!("PARITY FAIL fog exec: pooled != serial outputs");
        return 1;
    }
    // the sharded plan is configuration-identical to `plan` at
    // kt = 1, so only build/measure it when it can actually shard
    let plan_t = if max_threads > 1 {
        let p = BatchedBspPlan::with_threads(&fg, &assignment, 4,
                                             "gcn", max_threads)
            .unwrap();
        let pooled_t = p.execute(&fg.features, f_in, &wb, batch);
        let serial_t = p.execute_serial(&fg.features, f_in, &wb,
                                        batch);
        if pooled_t.outputs != serial_t.outputs
            || pooled_t.outputs != pooled.outputs
        {
            eprintln!(
                "PARITY FAIL fog exec: sharded pool deviates from \
                 serial/single-threaded outputs"
            );
            return 1;
        }
        Some(p)
    } else {
        None
    };
    let rb = bench("exec/pool_batched_b8_4fogs", min_s.max(0.2),
                   10_000, || {
        black_box(plan.execute_timings(&fg.features, f_in, &wb, batch));
    });
    let rs = bench("exec/pool_serial_8x_b1_4fogs", min_s.max(0.2),
                   10_000, || {
        for _ in 0..batch {
            black_box(plan.execute_timings(&fg.features, f_in, &wb, 1));
        }
    });
    let rt = plan_t.as_ref().map(|p| {
        bench(
            &format!("exec/pool_batched_b8_4fogs_kt{max_threads}"),
            min_s.max(0.2),
            10_000,
            || {
                black_box(p.execute_timings(&fg.features, f_in, &wb,
                                            batch));
            },
        )
    });
    let fog_speedup = rs.p50_ns / rb.p50_ns;
    println!(
        "fog exec v={fnv} b={batch}  serial {:>8.2} ms  batched \
         {:>8.2} ms  {:>5.2}x{}",
        rs.p50_ns / 1e6,
        rb.p50_ns / 1e6,
        fog_speedup,
        match &rt {
            Some(r) => format!("  (kt{max_threads} batched \
                                {:>8.2} ms)",
                               r.p50_ns / 1e6),
            None => String::new(),
        }
    );
    let mut fog_fields = vec![
        ("vertices", num(fnv as f64)),
        ("fogs", num(4.0)),
        ("batch", num(batch as f64)),
        ("model", s("gcn")),
        ("serial_ms", num(rs.p50_ns / 1e6)),
        ("batched_ms", num(rb.p50_ns / 1e6)),
        ("speedup", num(fog_speedup)),
    ];
    if let Some(r) = &rt {
        fog_fields.push(("kernel_threads", num(max_threads as f64)));
        fog_fields.push(("batched_sharded_ms", num(r.p50_ns / 1e6)));
    }
    let fog_rows = vec![obj(fog_fields)];

    // ---- recorder overhead: traced vs untraced kernel loop --------------
    // The flight-recorder contract (obs::recorder): a disabled recorder
    // costs ~one branch per call site, and enabled tracing stays under
    // RECORDER_GATE_PCT on a serving-shaped kernel loop — per-fog,
    // per-layer spans plus registry phase accounting wrapped around real
    // GEMM work, the same shape the measured fabric emits per batch.
    // The enabled figure is GATED, so a recorder hot-path regression
    // fails bench-kernels exactly like a kernel parity break would.
    let (rec_overhead_doc, rec_overhead_hist) = {
        let (n, fi, fo) = (1024usize, 128usize, 128usize);
        let mut rng = Rng::new(0x0B5E);
        let x: Vec<f32> =
            (0..n * fi).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let w: Vec<f32> =
            (0..fi * fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let b: Vec<f32> =
            (0..fo).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let spans_per_iter = 8usize; // 4 fogs x 2 layers
        let run_traced = |rec: &Arc<Recorder>, ring: &Ring| {
            for j in 0..4usize {
                for l in 0..2usize {
                    let t = rec.wall_now_us();
                    rec.span(ring,
                             SpanEvent::new(Phase::Kernel, 0, t, 0.0)
                                 .fog(j)
                                 .layer(l)
                                 .on_wall());
                    rec.registry()
                        .record_phase(0, j as i32, Phase::Kernel, 1e-6);
                }
                rec.registry()
                    .record_phase(0, j as i32, Phase::Sync, 1e-7);
            }
            black_box(gemm::gemm_bias(&x, n, fi, &w, fo, &b));
        };
        let r_base = bench("obs/kernel_untraced", min_s, 10_000, || {
            black_box(gemm::gemm_bias(&x, n, fi, &w, fo, &b));
        });
        let rec_off = Recorder::disabled();
        let ring_off = rec_off.ring();
        let r_off = bench("obs/kernel_rec_disabled", min_s, 10_000,
                          || {
            run_traced(&rec_off, &ring_off);
        });
        let rec_on = Recorder::with_capacity(ClockMode::Wall, 1 << 16);
        let ring_on = rec_on.ring();
        let r_on = bench("obs/kernel_rec_enabled", min_s, 10_000, || {
            run_traced(&rec_on, &ring_on);
        });
        // raw ring-push cost, amortized (the spans-only inner loop)
        let r_push = bench("obs/span_push_x1024", min_s.min(0.1),
                           10_000, || {
            for i in 0..1024u32 {
                rec_on.span(&ring_on,
                            SpanEvent::new(Phase::Kernel, 0,
                                           i as f64, 1.0)
                                .on_wall());
            }
        });
        let push_ns = r_push.p50_ns / 1024.0;
        let en_pct =
            (r_on.p50_ns - r_base.p50_ns) / r_base.p50_ns * 100.0;
        let dis_pct =
            (r_off.p50_ns - r_base.p50_ns) / r_base.p50_ns * 100.0;
        // relative gate plus a 50 us absolute epsilon so sub-ms jitter
        // on a shared host cannot trip it
        if r_on.p50_ns
            > r_base.p50_ns * (1.0 + RECORDER_GATE_PCT / 100.0)
                + 50_000.0
        {
            eprintln!(
                "OVERHEAD FAIL recorder: enabled tracing costs \
                 {en_pct:.2}% on the kernel loop \
                 (gate <{RECORDER_GATE_PCT}%)"
            );
            return 1;
        }
        println!(
            "recorder  untraced {:>8.2} ms  disabled {:>8.2} ms \
             ({dis_pct:+.2}%)  enabled {:>8.2} ms ({en_pct:+.2}%)  \
             push {push_ns:.0} ns/ev  gate <{RECORDER_GATE_PCT}%",
            r_base.p50_ns / 1e6,
            r_off.p50_ns / 1e6,
            r_on.p50_ns / 1e6
        );
        (
            obj(vec![
                ("shape", s("gemm_1024x128x128")),
                ("spans_per_iter", num(spans_per_iter as f64)),
                ("untraced_ms", num(r_base.p50_ns / 1e6)),
                ("disabled_ms", num(r_off.p50_ns / 1e6)),
                ("enabled_ms", num(r_on.p50_ns / 1e6)),
                ("disabled_overhead_pct", num(dis_pct)),
                ("enabled_overhead_pct", num(en_pct)),
                ("span_push_ns", num(push_ns)),
                ("gate_pct", num(RECORDER_GATE_PCT)),
            ]),
            obj(vec![
                ("enabled_pct", num(en_pct)),
                ("disabled_pct", num(dis_pct)),
                ("span_push_ns", num(push_ns)),
            ]),
        )
    };

    println!(
        "min speedups: gemm {min_gemm_speedup:.2}x, spmm \
         {min_spmm_speedup:.2}x (parity ok at {PARITY_TOL} rel, \
         sharded/pooled/serial bitwise-identical)"
    );

    let doc = obj(vec![
        ("benchmark", s("kernels")),
        ("generated_by", s("repro bench-kernels")),
        // all _ms / throughput / speedup values are p50-of-samples
        // (robust on noisy shared hosts)
        ("stat", s("p50")),
        ("smoke", Json::Bool(smoke)),
        ("simd", s(simd::name())),
        ("kernel_threads", num(max_threads as f64)),
        ("min_rows_per_shard", num(min_rows as f64)),
        ("min_rows_per_shard_source", s(min_rows_source)),
        ("gemm", arr(gemm_rows)),
        ("spmm", arr(spmm_rows)),
        ("simd_margin", arr(simd_rows)),
        ("thread_scaling", arr(scaling_rows)),
        ("fog_exec", arr(fog_rows)),
        ("recorder_overhead", rec_overhead_doc),
        (
            "summary",
            obj(vec![
                ("min_gemm_speedup", num(min_gemm_speedup)),
                ("min_spmm_speedup", num(min_spmm_speedup)),
                ("fog_batched_speedup", num(fog_speedup)),
                (
                    "gemm_scaling_at_max_workers",
                    num_or_null(gemm_scaling_max),
                ),
                (
                    "spmm_scaling_at_max_workers",
                    num_or_null(spmm_scaling_max),
                ),
                ("parity_tol_rel", num(PARITY_TOL as f64)),
            ]),
        ),
        (
            "peak_rss_bytes",
            peak_rss_bytes().map_or(Json::Null, |b| num(b as f64)),
        ),
    ]);
    if let Err(e) = std::fs::write(out_path, format!("{doc}\n")) {
        eprintln!("cannot write {out_path}: {e}");
        return 1;
    }
    println!("wrote {out_path}");

    // ---- bench history: one line per run, committed ---------------------
    let gentries: Vec<(&str, Json)> = gemm_speedups
        .iter()
        .map(|(k, v)| (k.as_str(), num(*v)))
        .collect();
    let sentries: Vec<(&str, Json)> = spmm_speedups
        .iter()
        .map(|(k, v)| (k.as_str(), num(*v)))
        .collect();
    let date = utc_date_string();
    let rev = git_rev();
    let line = obj(vec![
        ("date", s(&date)),
        ("rev", s(&rev)),
        ("stat", s("p50")),
        ("smoke", Json::Bool(smoke)),
        ("simd", s(simd::name())),
        ("kernel_threads", num(max_threads as f64)),
        ("min_rows_per_shard", num(min_rows as f64)),
        ("min_rows_per_shard_source", s(min_rows_source)),
        ("gemm_speedups", obj(gentries)),
        ("spmm_speedups", obj(sentries)),
        ("fog_batched_speedup", num(fog_speedup)),
        ("recorder_overhead", rec_overhead_hist),
        (
            "scaling_at_max_workers",
            obj(vec![
                ("gemm", num_or_null(gemm_scaling_max)),
                ("spmm", num_or_null(spmm_scaling_max)),
            ]),
        ),
        (
            "peak_rss_bytes",
            peak_rss_bytes().map_or(Json::Null, |b| num(b as f64)),
        ),
    ]);
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(history_path)
        .and_then(|mut fh| writeln!(fh, "{line}"));
    match appended {
        Ok(()) => {
            println!("appended {history_path}");
            0
        }
        Err(e) => {
            eprintln!("cannot append {history_path}: {e}");
            1
        }
    }
}
