//! Shared experiment context: dataset/engine plumbing, per-node offline
//! calibration (the ω models every planner call needs), and report
//! collection.

use std::collections::HashMap;
use std::path::PathBuf;

use crate::graph::{datasets, DatasetSpec, Graph};
use crate::profile::{calibration, PerfModel};
use crate::runtime::{Engine, EngineKind};
use crate::serving::metrics::{average, ServingReport};
use crate::serving::{serve, ServeOpts};
use crate::fog::Cluster;

pub struct Ctx {
    pub data_dir: PathBuf,
    pub artifacts_dir: PathBuf,
    pub engine_kind: EngineKind,
    pub repeats: usize,
    pub results_dir: PathBuf,
    graphs: HashMap<String, Graph>,
    engines: HashMap<&'static str, Engine>,
    /// HOST-time ω per (model, dataset) — node multipliers are applied by
    /// the cost model / serving pipeline, so one calibration serves all
    /// node types.
    omegas: HashMap<(String, String), PerfModel>,
}

impl Ctx {
    pub fn new(data_dir: &str, artifacts_dir: &str, engine_kind: EngineKind,
               repeats: usize) -> Ctx {
        Ctx {
            data_dir: PathBuf::from(data_dir),
            artifacts_dir: PathBuf::from(artifacts_dir),
            engine_kind,
            repeats,
            results_dir: PathBuf::from("results"),
            graphs: HashMap::new(),
            engines: HashMap::new(),
            omegas: HashMap::new(),
        }
    }

    pub fn graph(&mut self, name: &str) -> &Graph {
        if !self.graphs.contains_key(name) {
            let g = datasets::load_or_generate(&self.data_dir, name)
                .expect("experiment dataset");
            self.graphs.insert(name.to_string(), g);
        }
        &self.graphs[name]
    }

    pub fn spec(&self, name: &str) -> DatasetSpec {
        datasets::spec_by_name(name).expect("unknown dataset")
    }

    /// The engine (one per kind, shared across experiments so PJRT
    /// executable compilation amortizes).
    pub fn engine(&mut self, kind: EngineKind) -> &mut Engine {
        let key = match kind {
            EngineKind::Pjrt => "pjrt",
            EngineKind::Reference => "ref",
            EngineKind::Csr => "csr",
        };
        if !self.engines.contains_key(key) {
            let eng = match Engine::new(kind, &self.artifacts_dir) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!(
                        "warn: {kind:?} engine unavailable ({e}); using \
                         reference engine"
                    );
                    Engine::new(EngineKind::Reference, &self.artifacts_dir)
                        .expect("reference engine")
                }
            };
            self.engines.insert(key, eng);
        }
        self.engines.get_mut(key).unwrap()
    }

    pub fn default_engine(&mut self) -> &mut Engine {
        self.engine(self.engine_kind)
    }

    /// Offline proxy-guided calibration (paper §III-B): fit ω for
    /// (model, dataset) by measuring the engine on sampled subgraphs.
    pub fn omega(&mut self, model: &str, dataset: &str) -> PerfModel {
        let key = (model.to_string(), dataset.to_string());
        if let Some(m) = self.omegas.get(&key) {
            return m.clone();
        }
        let g = self.graph(dataset).clone();
        let spec = self.spec(dataset);
        let kind = self.engine_kind;
        let engine = self.engine(kind);
        let set = calibration::calibration_set(
            &g,
            &[0.05, 0.12, 0.25, 0.45],
            5,
            0xCA11B ^ model.len() as u64,
        );
        let f_in = spec.input_dim();
        let classes = spec.classes.max(1);
        let num_layers = crate::runtime::reference::model_layers(model);
        let model_s = model.to_string();
        let ds = dataset.to_string();
        let perf = calibration::profile_node(&set, |sub| {
            // measure a full forward over the subgraph (host seconds)
            let n = sub.n_total();
            let h0 = vec![0.5f32; n * f_in];
            let mut total = 0.0;
            if model_s == "astgcn" {
                let out = engine
                    .run_astgcn(&ds, &h0, n, f_in, sub)
                    .expect("calibration astgcn");
                total += out.host_seconds;
            } else {
                let edges = crate::runtime::pad::prep_edges(&model_s, sub)
                    .expect("calibration model");
                let mut h = h0;
                let mut dim = f_in;
                for layer in 0..num_layers {
                    let out = engine
                        .run_layer(&model_s, &ds, layer, &h, dim, &edges,
                                   f_in, classes)
                        .expect("calibration layer");
                    total += out.host_seconds;
                    // rebuild the full local-space state (halo zeroed),
                    // as the BSP loop does between layers
                    let mut st = vec![0f32; n * out.out_dim];
                    st[..edges.n_local * out.out_dim]
                        .copy_from_slice(&out.h);
                    h = st;
                    dim = out.out_dim;
                }
            }
            total
        });
        self.omegas.insert(key, perf.clone());
        perf
    }

    pub fn omegas_for(&mut self, model: &str, dataset: &str, n: usize)
                      -> Vec<PerfModel> {
        vec![self.omega(model, dataset); n]
    }

    /// Serve with repeats and average.
    pub fn run(&mut self, dataset: &str, cluster: &Cluster,
               opts: &ServeOpts) -> ServingReport {
        let g = self.graph(dataset).clone();
        let spec = self.spec(dataset);
        let omegas = self.omegas_for(&opts.model.clone(), dataset,
                                     cluster.len());
        let repeats = self.repeats;
        let kind = self.engine_kind;
        let engine = self.engine(kind);
        let mut reports = Vec::new();
        // one discarded warmup run absorbs lazy-compile/first-touch costs
        let total = repeats.max(1) + 1;
        for i in 0..total {
            match serve(&g, &spec, cluster, opts, &omegas, engine) {
                Ok(r) => {
                    if i > 0 || total == 1 {
                        reports.push(r);
                    }
                }
                Err(e) => panic!("serving failed: {e}"),
            }
        }
        average(reports)
    }

    /// Persist an experiment section to results/<id>.md and echo it.
    pub fn emit(&self, id: &str, markdown: &str) {
        println!("{markdown}");
        if std::fs::create_dir_all(&self.results_dir).is_ok() {
            let _ = std::fs::write(
                self.results_dir.join(format!("{id}.md")),
                markdown,
            );
        }
    }
}
