//! §II-C motivation measurements: Fig. 3 (cloud vs single-fog vs multi-fog
//! latency + stage breakdown across 4G/5G/WiFi) and Fig. 4 (vertex count
//! vs execution latency per fog under the equal-split multi-fog baseline).

use crate::compress::Codec;
use crate::fog::Cluster;
use crate::net::NetKind;
use crate::serving::{Placement, ServeOpts};

use super::context::Ctx;
use super::tables::{f2, f3, pct, speedup, Table};

pub fn fig3(ctx: &mut Ctx) -> String {
    let mut out = String::from(
        "## Fig. 3 — GNN serving latency: cloud vs single-fog vs multi-fog\n\n\
         Workload: GCN on the SIoT twin, 8 source devices; multi-fog is the\n\
         6-node testbed with the straw-man placement (min-cut partitions,\n\
         random mapping), no compression anywhere — the paper's §II-C setup.\n\n",
    );
    let mut t = Table::new(&[
        "net", "system", "total (s)", "collect (s)", "exec (s)",
        "collect %", "speedup vs cloud",
    ]);
    for net in NetKind::all() {
        let mut cloud_total = 0.0;
        let mut cloud_collect = 0.0;
        for sys in ["cloud", "single-fog", "multi-fog"] {
            let (cluster, opts) = match sys {
                "cloud" => (
                    Cluster::cloud(net),
                    ServeOpts {
                        wan: true,
                        ..ServeOpts::new("gcn", Placement::SingleNode(0),
                                         Codec::None)
                    },
                ),
                "single-fog" => {
                    let c = Cluster::testbed(net);
                    let p = c.most_powerful();
                    (c, ServeOpts::new("gcn", Placement::SingleNode(p),
                                       Codec::None))
                }
                _ => (
                    Cluster::testbed(net),
                    ServeOpts::new("gcn", Placement::MetisRandom(4),
                                   Codec::None),
                ),
            };
            let r = ctx.run("siot", &cluster, &opts);
            if sys == "cloud" {
                cloud_total = r.total_s;
                cloud_collect = r.collection_s;
            }
            t.row(vec![
                net.name().into(),
                sys.into(),
                f3(r.total_s),
                f3(r.collection_s),
                f3(r.execution_s + r.sync_s),
                pct(r.comm_fraction()),
                speedup(cloud_total, r.total_s),
            ]);
            if sys == "single-fog" {
                let red = 1.0 - r.collection_s / cloud_collect;
                out.push_str(&format!(
                    "- {}: single-fog cuts data collection by {:.0}% \
                     (paper: 64/67/61%)\n",
                    net.name(),
                    red * 100.0
                ));
            }
        }
    }
    out.push('\n');
    out.push_str(&t.to_markdown());
    out
}

pub fn fig4(ctx: &mut Ctx) -> String {
    let mut out = String::from(
        "## Fig. 4 — load distribution in straw-man multi-fog (SIoT, GCN, 4G)\n\n\
         Equal vertex counts, unequal execution latency — the heterogeneity\n\
         gap that motivates the IEP.\n\n",
    );
    let cluster = Cluster::testbed(NetKind::Cell4G);
    let opts = ServeOpts::new("gcn", Placement::MetisRandom(4), Codec::None);
    let r = ctx.run("siot", &cluster, &opts);
    let mut t = Table::new(&["fog", "type", "vertices", "exec (s)"]);
    for (j, node) in cluster.nodes.iter().enumerate() {
        t.row(vec![
            format!("{}", j + 1),
            node.node_type.name().into(),
            format!("{}", r.per_fog_vertices[j]),
            f3(r.per_fog_exec_s[j]),
        ]);
    }
    out.push_str(&t.to_markdown());
    let vmax = *r.per_fog_vertices.iter().max().unwrap() as f64;
    let vmin = *r.per_fog_vertices.iter().min().unwrap() as f64;
    let emax = r.per_fog_exec_s.iter().cloned().fold(0.0, f64::max);
    let emin = r.per_fog_exec_s.iter().cloned().fold(f64::MAX, f64::min);
    out.push_str(&format!(
        "\nvertex imbalance {}: exec imbalance {} — balanced counts, \
         skewed load (paper's observation).\n",
        f2(vmax / vmin.max(1.0)),
        f2(emax / emin.max(1e-9)),
    ));
    out
}
