//! Fig. 17 (scalability: RMAT-20K…100K across 1–6 type-B fogs) and
//! Fig. 18 (GPU enhancement on RMAT-100K, incl. the single-fog OOM).
//!
//! These sweeps default to the reference engine (homogeneous type-B
//! clusters make the LBAP mapping trivial and PJRT bucket padding cost
//! would dominate the single host core without changing the shape); pass
//! `--engine pjrt` to force the AOT path.

use crate::fog::Cluster;
use crate::net::NetKind;
use crate::profile::PerfModel;
use crate::runtime::EngineKind;
use crate::serving::{serve, Placement, ServeOpts};

use super::context::Ctx;
use super::tables::{f3, Table};

const FOG_COUNTS: [usize; 5] = [1, 2, 3, 4, 6];

fn run_one(ctx: &mut Ctx, dataset: &str, n_fogs: usize, gpu: bool)
           -> crate::serving::ServingReport {
    let g = ctx.graph(dataset).clone();
    let spec = ctx.spec(dataset);
    let mut cluster = Cluster::uniform_b(n_fogs, NetKind::Wifi);
    if gpu {
        cluster = cluster.with_gpus();
    }
    let placement = if n_fogs == 1 {
        Placement::SingleNode(0)
    } else {
        Placement::Iep
    };
    let opts = ServeOpts::new("gcn", placement, ServeOpts::co_codec(&g));
    // homogeneous cluster: the uncalibrated ω is sufficient for mapping
    let omegas = vec![PerfModel::uncalibrated(); n_fogs];
    let kind = ctx.engine_kind;
    let repeats = ctx.repeats.max(1);
    let engine = ctx.engine(kind);
    let mut reports = Vec::new();
    for _ in 0..repeats {
        reports.push(
            serve(&g, &spec, &cluster, &opts, &omegas, engine)
                .expect("scalability serve"),
        );
        if reports.last().unwrap().oom {
            break;
        }
    }
    crate::serving::metrics::average(reports)
}

pub fn fig17(ctx: &mut Ctx) -> String {
    let engine_note = match ctx.engine_kind {
        EngineKind::Pjrt => "PJRT (AOT artifacts)",
        EngineKind::Reference => "reference",
        EngineKind::Csr => "sparse CSR",
    };
    let mut t = Table::new(&[
        "dataset", "1 fog (s)", "2 fogs (s)", "3 fogs (s)", "4 fogs (s)",
        "6 fogs (s)",
    ]);
    for ds in ["rmat20k", "rmat40k", "rmat60k", "rmat80k", "rmat100k"] {
        let mut cells = vec![ds.to_string()];
        for &n in &FOG_COUNTS {
            let r = run_one(ctx, ds, n, false);
            cells.push(if r.oom { "OOM".into() } else { f3(r.total_s) });
        }
        t.row(cells);
    }
    format!(
        "## Fig. 17 — scalability over RMAT twins × type-B fog count \
         (engine: {engine_note})\n\n{}\n\
         Expected shape: latency shrinks with added fogs, biggest graphs\n\
         benefit most, curves converge once resources are ample.\n",
        t.to_markdown()
    )
}

pub fn fig18(ctx: &mut Ctx) -> String {
    let mut t = Table::new(&[
        "fogs", "CPU only (s)", "with GTX-1050 (s)", "GPU gain",
    ]);
    for &n in &FOG_COUNTS {
        let cpu = run_one(ctx, "rmat100k", n, false);
        let gpu = run_one(ctx, "rmat100k", n, true);
        let gain = if gpu.oom || cpu.oom {
            "-".to_string()
        } else {
            format!("{:.2}x", cpu.total_s / gpu.total_s)
        };
        t.row(vec![
            format!("{n}"),
            if cpu.oom { "OOM".into() } else { f3(cpu.total_s) },
            if gpu.oom { "OOM".into() } else { f3(gpu.total_s) },
            gain,
        ]);
    }
    format!(
        "## Fig. 18 — GPU enhancement (RMAT-100K, GCN)\n\n{}\n\
         Expected shape: single GPU fog OOMs (2 GiB device memory); GPU\n\
         gains are largest when fog resources are scarce; Fograph on CPUs\n\
         can still beat the straw-man fog with GPUs (paper Fig. 18).\n",
        t.to_markdown()
    )
}
