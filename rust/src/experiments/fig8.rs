//! Fig. 8 — IEP vs straw-man mapping strategies (METIS+Random,
//! METIS+Greedy) across the three environments E1/E2/E3 and the three
//! static GNN models.

use crate::compress::Codec;
use crate::fog::Cluster;
use crate::serving::{Placement, ServeOpts};

use super::context::Ctx;
use super::tables::{f3, pct, Table};

pub fn run(ctx: &mut Ctx) -> String {
    let mut out = String::from(
        "## Fig. 8 — IEP vs METIS+Random / METIS+Greedy (SIoT)\n\n\
         E1 = {1A,4B,1C, 4G}, E2 = {1A,4B,1C, 5G}, E3 = {1A,2B,1C, WiFi}.\n\
         Paper: IEP beats METIS+Greedy by 10.9/19.1/19.5% on average per\n\
         model config.\n\n",
    );
    let mut t = Table::new(&[
        "env", "model", "METIS+Random (s)", "METIS+Greedy (s)", "IEP (s)",
        "IEP vs Greedy",
    ]);
    let mut per_model_red: Vec<(String, Vec<f64>)> = Vec::new();
    for model in ["gcn", "gat", "sage"] {
        let mut reds = Vec::new();
        for env in ["E1", "E2", "E3"] {
            let cluster = Cluster::env(env).unwrap();
            let mk = |p: Placement| {
                ServeOpts::new(model, p, Codec::None)
            };
            // average random over seeds (it is stochastic by design)
            let mut rand_total = 0.0;
            let seeds = 3;
            for s in 0..seeds {
                rand_total += ctx
                    .run("siot", &cluster,
                         &mk(Placement::MetisRandom(100 + s)))
                    .total_s;
            }
            let rand = rand_total / seeds as f64;
            let greedy =
                ctx.run("siot", &cluster, &mk(Placement::MetisGreedy));
            let iep = ctx.run("siot", &cluster, &mk(Placement::Iep));
            let red = 1.0 - iep.total_s / greedy.total_s;
            reds.push(red);
            t.row(vec![
                env.into(),
                model.into(),
                f3(rand),
                f3(greedy.total_s),
                f3(iep.total_s),
                pct(red),
            ]);
        }
        per_model_red.push((model.to_string(), reds));
    }
    out.push_str(&t.to_markdown());
    out.push('\n');
    for (model, reds) in per_model_red {
        let avg = reds.iter().sum::<f64>() / reds.len() as f64;
        out.push_str(&format!(
            "- {model}: average IEP-vs-Greedy latency reduction {}\n",
            pct(avg)
        ));
    }
    out
}
