//! Fig. 14 — profiler fidelity: predicted vs actual execution latency of
//! fresh (unseen) subgraphs for every model × dataset, with the paper's
//! ±10% band check and ordering preservation.
//!
//! Runs on the reference engine: the PJRT path quantizes latency to the
//! bucket ladder by design (a step function a linear ω cannot and should
//! not fit — the bucketed runtime is profiled per bucket instead), while
//! the paper's PyG backend scales continuously with subgraph size.

use crate::profile::calibration;
use crate::profile::Cardinality;

use super::context::Ctx;
use super::tables::{pct, Table};

pub fn run(ctx: &mut Ctx) -> String {
    let mut out = String::from(
        "## Fig. 14 — profiler: predicted vs actual execution latency\n\n\
         Models are fitted on the calibration set (§III-B), then evaluated\n\
         on freshly sampled subgraphs; the paper's claim is every point\n\
         within the ±10% band and preserved ordering.\n\n",
    );
    let mut t = Table::new(&[
        "model", "dataset", "R^2 (fit)", "within ±10%", "within ±20%",
        "ordering preserved",
    ]);
    let mut csv = String::from("model,dataset,actual_s,predicted_s\n");
    for (model, dataset) in [
        ("gcn", "siot"),
        ("gat", "siot"),
        ("sage", "siot"),
        ("gcn", "yelp"),
        ("gat", "yelp"),
        ("sage", "yelp"),
    ] {
        let omega = ctx.omega(model, dataset);
        // fresh evaluation subgraphs (different seed than calibration)
        let g = ctx.graph(dataset).clone();
        let spec = ctx.spec(dataset);
        let set = calibration::calibration_set(
            &g,
            &[0.08, 0.18, 0.35, 0.55],
            4,
            0xE7A1,
        );
        let f_in = spec.input_dim();
        let classes = spec.classes.max(1);
        let kind = ctx.engine_kind;
        let engine = ctx.engine(kind);
        let mut pairs: Vec<(f64, f64)> = Vec::new(); // (actual, predicted)
        for sub in &set {
            let n = sub.n_total();
            let edges = crate::runtime::pad::prep_edges(model, sub)
                .expect("fig14 model");
            // median of 3 measurements: sub-millisecond single-shot
            // wall-clock has ±15% jitter on a busy single core
            let mut meas = Vec::with_capacity(3);
            for _ in 0..3 {
                let h0 = vec![0.5f32; n * f_in];
                let mut actual = 0.0;
                let mut h = h0;
                let mut dim = f_in;
                for layer in 0..2 {
                    let o = engine
                        .run_layer(model, dataset, layer, &h, dim, &edges,
                                   f_in, classes)
                        .expect("fig14 layer");
                    actual += o.host_seconds;
                    let mut st = vec![0f32; n * o.out_dim];
                    st[..edges.n_local * o.out_dim]
                        .copy_from_slice(&o.h);
                    h = st;
                    dim = o.out_dim;
                }
                meas.push(actual);
            }
            let actual = crate::util::stats::percentile(&meas, 50.0);
            let (v, e) = sub.cardinality();
            let predicted = omega.predict(Cardinality::new(v, e));
            pairs.push((actual, predicted));
            csv.push_str(&format!("{model},{dataset},{actual},{predicted}\n"));
        }
        let within = |band: f64| {
            pairs
                .iter()
                .filter(|(a, p)| (p - a).abs() / a.max(1e-9) <= band)
                .count() as f64
                / pairs.len() as f64
        };
        // ordering: larger actual -> larger predicted (Kendall-ish check)
        let mut concordant = 0usize;
        let mut total = 0usize;
        for i in 0..pairs.len() {
            for j in i + 1..pairs.len() {
                if (pairs[i].0 - pairs[j].0).abs() < 1e-6 {
                    continue;
                }
                total += 1;
                if (pairs[i].0 > pairs[j].0) == (pairs[i].1 > pairs[j].1) {
                    concordant += 1;
                }
            }
        }
        t.row(vec![
            model.into(),
            dataset.into(),
            format!("{:.4}", omega.r2),
            pct(within(0.10)),
            pct(within(0.20)),
            pct(concordant as f64 / total.max(1) as f64),
        ]);
    }
    let _ = std::fs::create_dir_all(&ctx.results_dir);
    let _ = std::fs::write(ctx.results_dir.join("fig14_scatter.csv"), csv);
    out.push_str(&t.to_markdown());
    out.push_str(
        "\nscatter points written to results/fig14_scatter.csv.\n",
    );
    out
}
