//! `repro scale` — the million-vertex scale tier (ROADMAP item 3):
//! sweeps `graph/generate` rmat and road-network graphs up toward the
//! memory cliff and measures the three scale-tier mechanisms together:
//!
//! * streamed grounding ([`GroundingStream`]) vs the materialize-all
//!   reference, with deterministic logical-bytes peaks proving the
//!   streamed path holds one sub-CSR + scratch instead of everything
//!   (`VmHWM` is a process high-water mark, so the within-run
//!   comparison uses heap-bytes accounting and the artifact records
//!   `peak_rss_bytes` once at the end);
//! * the spill-aware [`FeatureStore`] under a per-fog `--fog-mem-mb`
//!   budget that the resident-only path cannot satisfy at the top of
//!   the sweep — spill/rehydrate counts and bit-exactness are checked
//!   on every access (quantize-off spill codec);
//! * the indexed collection path ([`CollectionIndex`]) supplying the
//!   per-fog vertex lists for every access round without O(V) sweeps.
//!
//! Results land in BENCH_scale.json plus a provenance-stamped line in
//! BENCH_history.jsonl. Any gate violation (plan parity, spill
//! mismatch, streamed peak not below materialized, missing spills
//! under an infeasible budget) fails the command.

use std::io::Write;

use crate::compress::Codec;
use crate::graph::subgraph::{self, GroundingStream};
use crate::graph::{generate, Graph};
use crate::obs::clock::Stopwatch;
use crate::serving::collection::CollectionIndex;
use crate::serving::store::FeatureStore;
use crate::util::cli::{parse_fog_mem_mb, Args};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::provenance::{git_rev, peak_rss_bytes,
                              utc_date_string};
use crate::util::rng::Rng;

/// Feature width for the sweep: wide enough that feature residency —
/// not the CSR — is the memory axis, matching IoT window payloads.
const DIMS: usize = 32;
/// Spill granularity: rows per feature block
/// (4096 × 32 dims × 4 B = 512 KiB).
const BLOCK_ROWS: usize = 4096;
/// Access passes over every fog per sweep point.
const ACCESS_ROUNDS: usize = 3;
/// When `--fog-mem-mb` is absent: budget = 3/4 of the largest point's
/// per-fog feature bytes, so the top of the sweep must spill and the
/// bottom stays resident — the "memory cliff" shape by construction.
const AUTO_BUDGET_NUM: usize = 3;
const AUTO_BUDGET_DEN: usize = 4;

struct Point {
    topology: &'static str,
    vertices: usize,
    edges: usize,
}

fn sweep(smoke: bool) -> Vec<Point> {
    let mut pts = Vec::new();
    let rmat_v: &[usize] = if smoke {
        &[32_768, 65_536, 131_072]
    } else {
        &[262_144, 524_288, 1_048_576]
    };
    for &v in rmat_v {
        pts.push(Point { topology: "rmat", vertices: v, edges: 4 * v });
    }
    let road_v: &[usize] = if smoke {
        &[32_768, 65_536]
    } else {
        &[262_144, 1_048_576]
    };
    for &v in road_v {
        pts.push(Point {
            topology: "road",
            vertices: v,
            edges: v + v / 4,
        });
    }
    pts
}

fn generate_graph(p: &Point) -> Graph {
    match p.topology {
        "rmat" => generate::rmat(p.vertices, p.edges, 11,
                                 (0.57, 0.19, 0.19, 0.05)),
        "road" => generate::road_network(p.vertices, p.edges, 4, 13).0,
        other => unreachable!("unknown topology {other}"),
    }
}

fn rss_json() -> Json {
    match peak_rss_bytes() {
        Some(b) => num(b as f64),
        None => Json::Null,
    }
}

struct PointOutcome {
    row: Json,
    vps_per_fog: f64,
    spills: usize,
    rehydrates: usize,
    streamed_peak_bytes: usize,
    materialized_bytes: usize,
}

fn run_point(p: &Point, fogs: usize, budget_mb: usize)
             -> Result<PointOutcome, String> {
    let nv = p.vertices;
    let g = generate_graph(p);
    let mut rng = Rng::new(17 + nv as u64);
    let features: Vec<f32> =
        (0..nv * DIMS).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    // contiguous block placement: fog j owns an equal vertex range
    let assignment: Vec<u32> = (0..nv)
        .map(|v| (v as u64 * fogs as u64 / nv as u64) as u32)
        .collect();

    // ---- streamed grounding + store fill (one sub-CSR live) -----------
    let mut stores: Vec<FeatureStore> = (0..fogs)
        .map(|_| {
            FeatureStore::new(
                nv.div_ceil(fogs).div_ceil(BLOCK_ROWS),
                DIMS,
                Some(budget_mb),
                Codec::Lz4Only,
            )
        })
        .collect();
    let t = Stopwatch::start();
    let mut stream = GroundingStream::new(&g, &assignment, fogs);
    let mut streamed_peak = 0usize;
    let mut fog = 0usize;
    while let Some(sub) = stream.next_fog() {
        streamed_peak =
            streamed_peak.max(sub.heap_bytes() + stream.scratch_bytes());
        let owned = &sub.vertices[..sub.n_local];
        for (b, chunk) in owned.chunks(BLOCK_ROWS).enumerate() {
            let mut rows = Vec::with_capacity(chunk.len() * DIMS);
            for &v in chunk {
                let v = v as usize;
                rows.extend_from_slice(
                    &features[v * DIMS..(v + 1) * DIMS]);
            }
            let degrees: Vec<u64> = sub.global_degree
                [b * BLOCK_ROWS..b * BLOCK_ROWS + chunk.len()]
                .iter()
                .map(|&d| d as u64)
                .collect();
            stores[fog].insert(b, rows, degrees);
        }
        fog += 1;
    }
    let streamed_plan = stream.finish();
    let grounding_streamed_s = t.elapsed_s();
    streamed_peak = streamed_peak.max(streamed_plan.heap_bytes());

    // ---- materialize-all reference + plan parity at scale --------------
    let t = Stopwatch::start();
    let (m_subs, m_plan) =
        subgraph::extract_materialized(&g, &assignment, fogs);
    let grounding_materialized_s = t.elapsed_s();
    let materialized_bytes = m_subs
        .iter()
        .map(|sub| sub.heap_bytes())
        .sum::<usize>()
        + m_plan.heap_bytes();
    if m_plan != streamed_plan {
        return Err(format!(
            "{} V={nv}: streamed exchange plan differs from \
             materialized",
            p.topology
        ));
    }
    let halo_vertices = m_plan.total_vertices();
    drop(m_subs);
    drop(m_plan);
    if fogs > 1 && streamed_peak >= materialized_bytes {
        return Err(format!(
            "{} V={nv}: streamed grounding peak {streamed_peak} B not \
             below materialize-all {materialized_bytes} B",
            p.topology
        ));
    }

    // ---- access rounds through the bounded stores ----------------------
    let idx = CollectionIndex::build(&g, &assignment, fogs);
    let mut mismatches = 0usize;
    let mut rows_accessed = 0usize;
    let mut access_s = 0f64;
    for round in 0..ACCESS_ROUNDS {
        for jj in 0..fogs {
            // rotate the visit order so every round re-warms a
            // different fog first (LRU churn under the budget)
            let j = (jj + round) % fogs;
            let owned = &idx.by_fog[j];
            let n_blocks = owned.len().div_ceil(BLOCK_ROWS);
            for b in 0..n_blocks {
                let verts = &owned[b * BLOCK_ROWS
                    ..(b * BLOCK_ROWS + BLOCK_ROWS).min(owned.len())];
                let t = Stopwatch::start();
                let rows = stores[j].get(b);
                access_s += t.elapsed_s();
                rows_accessed += verts.len();
                for (i, &v) in verts.iter().enumerate() {
                    let v = v as usize;
                    let got = &rows[i * DIMS..(i + 1) * DIMS];
                    let want = &features[v * DIMS..(v + 1) * DIMS];
                    if got
                        .iter()
                        .zip(want)
                        .any(|(a, b)| a.to_bits() != b.to_bits())
                    {
                        mismatches += 1;
                    }
                }
            }
        }
    }
    if mismatches > 0 {
        return Err(format!(
            "{} V={nv}: {mismatches} spill-rehydrate row mismatches \
             (quantize-off spill must be bit-exact)",
            p.topology
        ));
    }
    let max_fog_feature_bytes = idx
        .by_fog
        .iter()
        .map(|verts| verts.len() * DIMS * 4)
        .max()
        .unwrap_or(0);
    let spills: usize =
        stores.iter().map(|st| st.stats().spills).sum();
    let rehydrates: usize =
        stores.iter().map(|st| st.stats().rehydrates).sum();
    let spilled_wire_bytes: usize =
        stores.iter().map(|st| st.stats().spilled_wire_bytes).sum();
    let peak_resident_bytes = stores
        .iter()
        .map(|st| st.stats().peak_resident_bytes)
        .max()
        .unwrap_or(0);
    // an infeasible budget (per-fog features exceed it) MUST have
    // spilled — otherwise the bound is fiction
    if max_fog_feature_bytes > budget_mb * (1 << 20) && spills == 0 {
        return Err(format!(
            "{} V={nv}: per-fog features {max_fog_feature_bytes} B \
             exceed the {budget_mb} MiB budget but nothing spilled",
            p.topology
        ));
    }
    let vps_per_fog = if access_s > 0.0 {
        rows_accessed as f64 / access_s / fogs as f64
    } else {
        0.0
    };

    println!(
        "{:>4} V={nv:>8} E={:>8}  ground {:>7.3}s (mat {:>7.3}s)  \
         peak {:>6.1} MiB (mat {:>6.1} MiB)  spills {spills:>3} \
         rehydrates {rehydrates:>3}  {:>9.0} vtx/s/fog",
        p.topology,
        g.num_edges(),
        grounding_streamed_s,
        grounding_materialized_s,
        streamed_peak as f64 / (1 << 20) as f64,
        materialized_bytes as f64 / (1 << 20) as f64,
        vps_per_fog,
    );

    let row = obj(vec![
        ("topology", s(p.topology)),
        ("vertices", num(nv as f64)),
        ("edges", num(g.num_edges() as f64)),
        ("fogs", num(fogs as f64)),
        ("dims", num(DIMS as f64)),
        ("grounding_streamed_s", num(grounding_streamed_s)),
        ("grounding_materialized_s", num(grounding_materialized_s)),
        ("streamed_peak_bytes", num(streamed_peak as f64)),
        ("materialized_bytes", num(materialized_bytes as f64)),
        ("halo_vertices", num(halo_vertices as f64)),
        ("max_fog_feature_bytes", num(max_fog_feature_bytes as f64)),
        ("fog_mem_mb", num(budget_mb as f64)),
        ("spills", num(spills as f64)),
        ("rehydrates", num(rehydrates as f64)),
        ("spill_rehydrate_mismatches", num(mismatches as f64)),
        ("peak_resident_bytes", num(peak_resident_bytes as f64)),
        ("spilled_wire_bytes", num(spilled_wire_bytes as f64)),
        ("access_rounds", num(ACCESS_ROUNDS as f64)),
        ("rows_accessed", num(rows_accessed as f64)),
        ("vertices_per_sec_per_fog", num(vps_per_fog)),
    ]);
    Ok(PointOutcome {
        row,
        vps_per_fog,
        spills,
        rehydrates,
        streamed_peak_bytes: streamed_peak,
        materialized_bytes,
    })
}

pub fn cmd(args: &Args) -> i32 {
    let smoke = args.has("smoke");
    let out_path = args.get_or("out", "BENCH_scale.json");
    let history_path = args.get_or("history", "BENCH_history.jsonl");
    let fogs = match args.get("fogs") {
        None => 6,
        Some(v) => match crate::util::cli::parse_bounded_usize(
            "--fogs", v, 2, 64) {
            Ok(n) => n,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
    };
    let flag_budget = match parse_fog_mem_mb(args) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if let Err(e) = crate::util::cli::probe_writable(out_path) {
        eprintln!("--out: {e}");
        return 2;
    }
    if let Err(e) = crate::util::cli::probe_writable(history_path) {
        eprintln!("--history: {e}");
        return 2;
    }

    let points = sweep(smoke);
    let top_v =
        points.iter().map(|p| p.vertices).max().unwrap_or(0);
    let (budget_mb, budget_source) = match flag_budget {
        Some(mb) => (mb, "flag"),
        None => {
            let per_fog = top_v.div_ceil(fogs) * DIMS * 4;
            let auto = (per_fog * AUTO_BUDGET_NUM / AUTO_BUDGET_DEN)
                >> 20;
            (auto.max(1), "auto")
        }
    };
    println!(
        "scale sweep: {} points, {fogs} fogs, dims {DIMS}, \
         budget {budget_mb} MiB/fog ({budget_source})",
        points.len()
    );

    let mut rows = Vec::new();
    let mut top_outcome: Option<PointOutcome> = None;
    for p in &points {
        match run_point(p, fogs, budget_mb) {
            Ok(out) => {
                let is_top =
                    p.topology == "rmat" && p.vertices == top_v;
                rows.push(out.row.clone());
                if is_top {
                    top_outcome = Some(out);
                }
            }
            Err(e) => {
                eprintln!("SCALE GATE FAIL: {e}");
                return 1;
            }
        }
    }

    let date = utc_date_string();
    let rev = git_rev();
    let doc = obj(vec![
        ("benchmark", s("scale")),
        ("generated_by", s("repro scale")),
        ("rev", s(&rev)),
        ("date", s(&date)),
        ("smoke", Json::Bool(smoke)),
        ("fogs", num(fogs as f64)),
        ("dims", num(DIMS as f64)),
        ("block_rows", num(BLOCK_ROWS as f64)),
        ("fog_mem_mb", num(budget_mb as f64)),
        ("fog_mem_mb_source", s(budget_source)),
        ("spill_codec", s("lz4only")),
        ("sweep", arr(rows)),
        ("peak_rss_bytes", rss_json()),
    ]);
    if let Err(e) = std::fs::write(out_path, format!("{doc}\n")) {
        eprintln!("cannot write {out_path}: {e}");
        return 1;
    }
    println!("wrote {out_path}");

    let top = top_outcome.expect("sweep always contains the top point");
    let line = obj(vec![
        ("date", s(&date)),
        ("rev", s(&rev)),
        ("benchmark", s("scale")),
        ("smoke", Json::Bool(smoke)),
        ("fogs", num(fogs as f64)),
        ("fog_mem_mb", num(budget_mb as f64)),
        ("top_vertices", num(top_v as f64)),
        ("top_vertices_per_sec_per_fog", num(top.vps_per_fog)),
        ("top_spills", num(top.spills as f64)),
        ("top_rehydrates", num(top.rehydrates as f64)),
        (
            "top_streamed_over_materialized",
            num(top.streamed_peak_bytes as f64
                / top.materialized_bytes.max(1) as f64),
        ),
        ("peak_rss_bytes", rss_json()),
    ]);
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(history_path)
        .and_then(|mut fh| writeln!(fh, "{line}"));
    match appended {
        Ok(()) => {
            println!("appended {history_path}");
            0
        }
        Err(e) => {
            eprintln!("cannot append {history_path}: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_monotone_per_topology() {
        for smoke in [true, false] {
            let pts = sweep(smoke);
            for topo in ["rmat", "road"] {
                let vs: Vec<usize> = pts
                    .iter()
                    .filter(|p| p.topology == topo)
                    .map(|p| p.vertices)
                    .collect();
                assert!(!vs.is_empty());
                assert!(vs.windows(2).all(|w| w[0] < w[1]), "{topo}");
            }
            // the full sweep reaches a million vertices
            if !smoke {
                assert!(pts.iter().any(|p| p.vertices >= 1_000_000));
            }
        }
    }

    #[test]
    fn tiny_point_end_to_end_gates_hold() {
        // a micro point exercising the same code path as the sweep:
        // budget 1 MiB vs ~2.2 MiB of features per fog forces spills
        let p = Point {
            topology: "rmat",
            vertices: 32_768,
            edges: 2 * 32_768,
        };
        let out = run_point(&p, 2, 1).expect("gates hold");
        assert!(out.spills > 0, "1 MiB budget must spill");
        assert!(out.rehydrates > 0);
        assert!(out.streamed_peak_bytes < out.materialized_bytes);
        assert!(out.vps_per_fog > 0.0);
    }
}
