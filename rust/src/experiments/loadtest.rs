//! Loadtest experiment — the four serving systems under identical
//! sustained traffic (same seeded arrival stream), reporting SLO-level
//! metrics instead of single-inference latency: goodput, latency
//! percentiles, shed rate and scheduler activity. This is the
//! request-level companion to the Fig. 11/12 comparisons.
//!
//! A second section exercises the multi-tenant serving fabric: a
//! bursty high-weight gcn tenant sharing the fograph cluster with a
//! low-weight Poisson sage tenant, under deficit-round-robin
//! weighted-fair admission vs. the shared-FIFO control. The burst
//! saturates the cluster, so the low-weight tenant's p99/goodput under
//! each policy is the fairness headline; the Jain index (over
//! weight-normalized goodput) summarizes it. Scenario rates are
//! derived from a measured capacity probe, so the contrast is
//! meaningful on any host.
//!
//! ω models are left uncalibrated on purpose: the whole run is then a
//! pure function of the seed, so regenerated tables are reproducible.

use crate::net::NetKind;
use crate::profile::PerfModel;
use crate::serving::pipeline;
use crate::traffic::{doc_json, fabric_json, report_json, run_fabric,
                     run_loadtest, ArrivalKind, FairPolicy,
                     TenantInput, TrafficConfig};

use super::context::Ctx;
use super::tables::{f1, pct, Table};

pub fn run(ctx: &mut Ctx) -> String {
    let dataset = "siot";
    let model = "gcn";
    let net = NetKind::Wifi;
    let g = ctx.graph(dataset).clone();
    let spec = ctx.spec(dataset);
    let traffic = TrafficConfig {
        arrival: ArrivalKind::Poisson,
        rps: 200.0,
        duration_s: 30.0,
        seed: 0x70AD,
        ..Default::default()
    };

    let mut table = Table::new(&[
        "system",
        "goodput (req/s)",
        "p50 (ms)",
        "p95 (ms)",
        "p99 (ms)",
        "shed",
        "diff/replan",
    ]);
    let mut runs = Vec::new();
    let mut goodput = std::collections::BTreeMap::new();
    let kind = ctx.engine_kind;
    for mode in pipeline::MODES {
        let (cluster, opts) = pipeline::mode_setup(mode, model, net, &g)
            .expect("known mode");
        let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
        let engine = ctx.engine(kind);
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, engine)
            .expect("loadtest run");
        let slo = &r.slo;
        table.row(vec![
            mode.to_string(),
            f1(slo.goodput_rps),
            f1(slo.latency.p50_s * 1e3),
            f1(slo.latency.p95_s * 1e3),
            f1(slo.latency.p99_s * 1e3),
            pct(slo.shed_rate()),
            format!("{}/{}", slo.diffusions, slo.replans),
        ]);
        goodput.insert(mode, slo.goodput_rps);
        runs.push(report_json(mode, &traffic, &r));
    }

    // ---- multi-tenant fairness: DRR vs shared-FIFO under a burst --------
    // capacity probe: saturate the fograph system once and take its
    // completion rate as the service capacity the scenario scales from
    let (cluster, opts) = pipeline::mode_setup("fograph", model, net, &g)
        .expect("known mode");
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
    let probe_traffic = TrafficConfig {
        rps: 4000.0,
        duration_s: 8.0,
        seed: 0x70AD,
        ..Default::default()
    };
    let probe = {
        let engine = ctx.engine(kind);
        run_loadtest(&g, &spec, &cluster, &opts, &probe_traffic,
                     &omegas, engine)
            .expect("capacity probe")
    };
    let cap = (probe.slo.completed as f64 / probe_traffic.duration_s)
        .max(50.0);

    let fabric_traffic = TrafficConfig {
        duration_s: 12.0,
        seed: 0x70AD,
        ..Default::default()
    };
    let mk_tenants = || {
        crate::traffic::tenant::burst_fairness_pair(
            &fabric_traffic, cap, "gcn", "sage", dataset)
    };
    let mut fair_table = Table::new(&[
        "policy",
        "tenant",
        "goodput (req/s)",
        "p99 (ms)",
        "shed",
        "jain",
    ]);
    let mut lo_summary = std::collections::BTreeMap::new();
    for fair in [FairPolicy::Drr, FairPolicy::Fifo] {
        let (hi, lo) = mk_tenants();
        let inputs: Vec<TenantInput<'_>> = [hi, lo]
            .into_iter()
            .map(|t| {
                let (_, topts) =
                    pipeline::mode_setup("fograph", &t.model, net, &g)
                        .expect("known mode");
                let omegas =
                    vec![PerfModel::uncalibrated_for(&t.model);
                         cluster.len()];
                TenantInput { tenant: t, g: &g, spec, opts: topts,
                              omegas }
            })
            .collect();
        let fr = {
            let engine = ctx.engine(kind);
            run_fabric(&cluster, inputs, &fabric_traffic, fair,
                       engine)
                .expect("fabric run")
        };
        for t in &fr.tenants {
            fair_table.row(vec![
                fair.name().to_string(),
                t.name.clone(),
                f1(t.slo.goodput_rps),
                f1(t.slo.latency.p99_s * 1e3),
                pct(t.slo.shed_rate()),
                format!("{:.3}", fr.fairness_jain),
            ]);
            if t.name == "lo-steady" {
                lo_summary.insert(
                    fair.name(),
                    (t.slo.goodput_rps, t.slo.latency.p99_s * 1e3),
                );
            }
        }
        runs.push(fabric_json(
            &format!("fograph-2tenant-{}", fair.name()),
            &fabric_traffic,
            &fr,
        ));
    }

    let doc = doc_json(dataset, "gcn+sage", net.name(), "analytic",
                       runs, Vec::new());
    let _ = std::fs::create_dir_all(&ctx.results_dir);
    let _ = std::fs::write(
        ctx.results_dir.join("loadtest.json"),
        format!("{doc}\n"),
    );

    let fog = goodput["fograph"];
    let cloud = goodput["cloud"];
    let gain = if cloud > 0.0 {
        format!("{:.2}x", fog / cloud)
    } else {
        "inf".to_string()
    };
    let (drr_good, drr_p99) =
        lo_summary.get("drr").copied().unwrap_or((0.0, 0.0));
    let (fifo_good, fifo_p99) =
        lo_summary.get("fifo").copied().unwrap_or((0.0, 0.0));
    format!(
        "## Loadtest — sustained traffic, identical streams (SIoT, GCN, \
         WiFi, {} {} req/s × {}s, SLO {:.0} ms)\n\n{}\n\
         goodput gain fograph vs cloud: {gain} (paper's headline \
         throughput gain: 6.84x at the single-inference level).\n\n\
         ### Multi-tenant fairness — bursty gcn (weight 4) vs Poisson \
         sage (weight 1) on shared fogs (capacity probe {cap:.0} \
         req/s)\n\n{}\n\
         low-weight tenant under the burst: p99 {drr_p99:.0} ms / \
         goodput {drr_good:.1} req/s with weighted-fair DRR vs p99 \
         {fifo_p99:.0} ms / goodput {fifo_good:.1} req/s under the \
         shared-FIFO control. Per-run records (per-tenant SLO \
         summaries, Jain index, plan-cache hit counts) in \
         results/loadtest.json.\n",
        traffic.arrival.name(),
        traffic.rps,
        traffic.duration_s,
        traffic.slo_s * 1e3,
        table.to_markdown(),
        fair_table.to_markdown(),
    )
}
