//! Loadtest experiment — the four serving systems under identical
//! sustained traffic (same seeded arrival stream), reporting SLO-level
//! metrics instead of single-inference latency: goodput, latency
//! percentiles, shed rate and scheduler activity. This is the
//! request-level companion to the Fig. 11/12 comparisons.
//!
//! ω models are left uncalibrated on purpose: the whole run is then a
//! pure function of the seed, so regenerated tables are reproducible.

use crate::net::NetKind;
use crate::profile::PerfModel;
use crate::serving::pipeline;
use crate::traffic::{doc_json, report_json, run_loadtest, ArrivalKind,
                     TrafficConfig};

use super::context::Ctx;
use super::tables::{f1, pct, Table};

pub fn run(ctx: &mut Ctx) -> String {
    let dataset = "siot";
    let model = "gcn";
    let net = NetKind::Wifi;
    let g = ctx.graph(dataset).clone();
    let spec = ctx.spec(dataset);
    let traffic = TrafficConfig {
        arrival: ArrivalKind::Poisson,
        rps: 200.0,
        duration_s: 30.0,
        seed: 0x70AD,
        ..Default::default()
    };

    let mut table = Table::new(&[
        "system",
        "goodput (req/s)",
        "p50 (ms)",
        "p95 (ms)",
        "p99 (ms)",
        "shed",
        "diff/replan",
    ]);
    let mut runs = Vec::new();
    let mut goodput = std::collections::BTreeMap::new();
    let kind = ctx.engine_kind;
    for mode in pipeline::MODES {
        let (cluster, opts) = pipeline::mode_setup(mode, model, net, &g)
            .expect("known mode");
        let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
        let engine = ctx.engine(kind);
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, engine)
            .expect("loadtest run");
        let slo = &r.slo;
        table.row(vec![
            mode.to_string(),
            f1(slo.goodput_rps),
            f1(slo.latency.p50_s * 1e3),
            f1(slo.latency.p95_s * 1e3),
            f1(slo.latency.p99_s * 1e3),
            pct(slo.shed_rate()),
            format!("{}/{}", slo.diffusions, slo.replans),
        ]);
        goodput.insert(mode, slo.goodput_rps);
        runs.push(report_json(mode, &traffic, &r));
    }

    let doc = doc_json(dataset, model, net.name(), "analytic", runs,
                       Vec::new());
    let _ = std::fs::create_dir_all(&ctx.results_dir);
    let _ = std::fs::write(
        ctx.results_dir.join("loadtest.json"),
        format!("{doc}\n"),
    );

    let fog = goodput["fograph"];
    let cloud = goodput["cloud"];
    let gain = if cloud > 0.0 {
        format!("{:.2}x", fog / cloud)
    } else {
        "inf".to_string()
    };
    format!(
        "## Loadtest — sustained traffic, identical streams (SIoT, GCN, \
         WiFi, {} {} req/s × {}s, SLO {:.0} ms)\n\n{}\n\
         goodput gain fograph vs cloud: {gain} (paper's headline \
         throughput gain: 6.84x at the single-inference level). \
         Per-run records in results/loadtest.json.\n",
        traffic.arrival.name(),
        traffic.rps,
        traffic.duration_s,
        traffic.slo_s * 1e3,
        table.to_markdown()
    )
}
