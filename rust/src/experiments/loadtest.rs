//! Loadtest experiment — the four serving systems under identical
//! sustained traffic (same seeded arrival stream), reporting SLO-level
//! metrics instead of single-inference latency: goodput, latency
//! percentiles, shed rate and scheduler activity. This is the
//! request-level companion to the Fig. 11/12 comparisons.
//!
//! A second section exercises the multi-tenant serving fabric: a
//! bursty high-weight gcn tenant sharing the fograph cluster with a
//! low-weight Poisson sage tenant, under deficit-round-robin
//! weighted-fair admission vs. the shared-FIFO control. The burst
//! saturates the cluster, so the low-weight tenant's p99/goodput under
//! each policy is the fairness headline; the Jain index (over
//! weight-normalized goodput) summarizes it. Scenario rates are
//! derived from a measured capacity probe, so the contrast is
//! meaningful on any host.
//!
//! A third section sweeps `--pipeline-depth` 1/2/4 in measured mode
//! at the probed saturation rate: the pipelined-executor headline
//! (goodput up, p99 held, per-fog occupancy) with one
//! provenance-stamped line appended to BENCH_history.jsonl per
//! regenerated sweep.
//!
//! A fourth section is the chaos sweep: one seeded fault per class
//! (fog crash+rejoin, a 0.3x straggler, a 0.1x link collapse) injected
//! at t=4 of a 12 s analytic run at the probed saturation rate, with
//! per-class time-to-detect / time-to-recover / SLO damage appended to
//! BENCH_history.jsonl — the resilience headline next to the
//! throughput one.
//!
//! ω models are left uncalibrated on purpose: the analytic sections
//! (the chaos sweep included) are then a pure function of the seed, so
//! regenerated tables are reproducible (the measured depth sweep is
//! wall-clock by design).

use crate::net::NetKind;
use crate::obs::Recorder;
use crate::profile::PerfModel;
use crate::runtime::kernels::DEFAULT_TASK_DEADLINE_S;
use crate::serving::pipeline;
use crate::traffic::{doc_json, fabric_json, report_json, run_fabric,
                     run_loadtest, run_loadtest_chaos, ArrivalKind,
                     ExecMode, FairPolicy, FaultSpec, TenantInput,
                     TrafficConfig};
use crate::util::json::{arr, num, obj, s};
use crate::util::provenance::{git_rev, utc_date_string};

use super::context::Ctx;
use super::tables::{f1, pct, Table};

pub fn run(ctx: &mut Ctx) -> String {
    let dataset = "siot";
    let model = "gcn";
    let net = NetKind::Wifi;
    let g = ctx.graph(dataset).clone();
    let spec = ctx.spec(dataset);
    let traffic = TrafficConfig {
        arrival: ArrivalKind::Poisson,
        rps: 200.0,
        duration_s: 30.0,
        seed: 0x70AD,
        ..Default::default()
    };

    let mut table = Table::new(&[
        "system",
        "goodput (req/s)",
        "p50 (ms)",
        "p95 (ms)",
        "p99 (ms)",
        "shed",
        "diff/replan",
    ]);
    let mut runs = Vec::new();
    let mut goodput = std::collections::BTreeMap::new();
    let kind = ctx.engine_kind;
    for mode in pipeline::MODES {
        let (cluster, opts) = pipeline::mode_setup(mode, model, net, &g)
            .expect("known mode");
        let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
        let engine = ctx.engine(kind);
        let r = run_loadtest(&g, &spec, &cluster, &opts, &traffic,
                             &omegas, engine)
            .expect("loadtest run");
        let slo = &r.slo;
        table.row(vec![
            mode.to_string(),
            f1(slo.goodput_rps),
            f1(slo.latency.p50_s * 1e3),
            f1(slo.latency.p95_s * 1e3),
            f1(slo.latency.p99_s * 1e3),
            pct(slo.shed_rate()),
            format!("{}/{}", slo.diffusions, slo.replans),
        ]);
        goodput.insert(mode, slo.goodput_rps);
        runs.push(report_json(mode, &traffic, &r));
    }

    // ---- multi-tenant fairness: DRR vs shared-FIFO under a burst --------
    // capacity probe: saturate the fograph system once and take its
    // completion rate as the service capacity the scenario scales from
    let (cluster, opts) = pipeline::mode_setup("fograph", model, net, &g)
        .expect("known mode");
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
    let probe_traffic = TrafficConfig {
        rps: 4000.0,
        duration_s: 8.0,
        seed: 0x70AD,
        ..Default::default()
    };
    let probe = {
        let engine = ctx.engine(kind);
        run_loadtest(&g, &spec, &cluster, &opts, &probe_traffic,
                     &omegas, engine)
            .expect("capacity probe")
    };
    let cap = (probe.slo.completed as f64 / probe_traffic.duration_s)
        .max(50.0);

    let fabric_traffic = TrafficConfig {
        duration_s: 12.0,
        seed: 0x70AD,
        ..Default::default()
    };
    let mk_tenants = || {
        crate::traffic::tenant::burst_fairness_pair(
            &fabric_traffic, cap, "gcn", "sage", dataset)
    };
    let mut fair_table = Table::new(&[
        "policy",
        "tenant",
        "goodput (req/s)",
        "p99 (ms)",
        "shed",
        "jain",
    ]);
    let mut lo_summary = std::collections::BTreeMap::new();
    for fair in [FairPolicy::Drr, FairPolicy::Fifo] {
        let (hi, lo) = mk_tenants();
        let inputs: Vec<TenantInput<'_>> = [hi, lo]
            .into_iter()
            .map(|t| {
                let (_, topts) =
                    pipeline::mode_setup("fograph", &t.model, net, &g)
                        .expect("known mode");
                let omegas =
                    vec![PerfModel::uncalibrated_for(&t.model);
                         cluster.len()];
                TenantInput { tenant: t, g: &g, spec, opts: topts,
                              omegas }
            })
            .collect();
        let fr = {
            let engine = ctx.engine(kind);
            run_fabric(&cluster, inputs, &fabric_traffic, fair,
                       engine)
                .expect("fabric run")
        };
        for t in &fr.tenants {
            fair_table.row(vec![
                fair.name().to_string(),
                t.name.clone(),
                f1(t.slo.goodput_rps),
                f1(t.slo.latency.p99_s * 1e3),
                pct(t.slo.shed_rate()),
                format!("{:.3}", fr.fairness_jain),
            ]);
            if t.name == "lo-steady" {
                lo_summary.insert(
                    fair.name(),
                    (t.slo.goodput_rps, t.slo.latency.p99_s * 1e3),
                );
            }
        }
        runs.push(fabric_json(
            &format!("fograph-2tenant-{}", fair.name()),
            &fabric_traffic,
            &fr,
        ));
    }

    // ---- pipelined measured depth sweep -----------------------------
    // the pipelining headline: at the measured saturation point,
    // deeper submission windows should raise goodput while p99 holds.
    // Capacity is probed in measured mode (real kernels, this host),
    // so the sweep saturates wherever it runs; numbers are wall-clock
    // and therefore host-specific, which is why the sweep is appended
    // to BENCH_history.jsonl with rev/date provenance rather than
    // compared against fixed thresholds.
    let m_probe_traffic = TrafficConfig {
        rps: 800.0,
        duration_s: 3.0,
        seed: 0x70AD,
        exec: ExecMode::Measured,
        kernel_threads: 2,
        ..Default::default()
    };
    let m_probe = {
        let engine = ctx.engine(kind);
        run_loadtest(&g, &spec, &cluster, &opts, &m_probe_traffic,
                     &omegas, engine)
            .expect("measured capacity probe")
    };
    let m_cap = (m_probe.slo.completed as f64
        / m_probe_traffic.duration_s)
        .max(25.0);
    let mut depth_table = Table::new(&[
        "depth",
        "goodput (req/s)",
        "p99 (ms)",
        "occupancy per fog",
        "stall (ms)",
    ]);
    let mut depth_rows = Vec::new();
    for depth in [1usize, 2, 4] {
        let t = TrafficConfig {
            arrival: ArrivalKind::Poisson,
            rps: m_cap,
            duration_s: 6.0,
            seed: 0x70AD,
            exec: ExecMode::Measured,
            kernel_threads: 2,
            pipeline_depth: depth,
            ..Default::default()
        };
        let r = {
            let engine = ctx.engine(kind);
            run_loadtest(&g, &spec, &cluster, &opts, &t, &omegas,
                         engine)
                .expect("depth sweep run")
        };
        let p = r.pipeline.clone()
            .expect("measured runs report pipeline");
        let occ: Vec<String> =
            p.occupancy.iter().map(|o| format!("{o:.2}")).collect();
        depth_table.row(vec![
            depth.to_string(),
            f1(r.slo.goodput_rps),
            f1(r.slo.latency.p99_s * 1e3),
            format!("[{}]", occ.join(" ")),
            f1(p.stall_s * 1e3),
        ]);
        depth_rows.push(obj(vec![
            ("depth", num(depth as f64)),
            ("goodput_rps", num(r.slo.goodput_rps)),
            ("p99_ms", num(r.slo.latency.p99_s * 1e3)),
            ("pipeline_occupancy",
             arr(p.occupancy.iter().copied().map(num))),
            ("pipeline_stall_ms", num(p.stall_s * 1e3)),
        ]));
        runs.push(report_json(
            &format!("fograph-measured-depth{depth}"), &t, &r));
    }
    // ---- chaos sweep: one fault per class at saturation -------------
    // analytic mode, so the whole sweep is a pure function of the
    // seed: same fault schedule, same detection times, same damage on
    // every host. Rate = the probed analytic capacity (the fault hits
    // a saturated system, which is where recovery is hardest).
    let chaos_traffic = TrafficConfig {
        arrival: ArrivalKind::Poisson,
        rps: cap,
        duration_s: 12.0,
        seed: 0x70AD,
        ..Default::default()
    };
    let mut fault_table = Table::new(&[
        "fault",
        "onset (s)",
        "detect (s)",
        "recover (s)",
        "p99 delta (ms)",
        "goodput dip",
        "shed",
        "hedges",
    ]);
    let mut fault_rows = Vec::new();
    let fmt_t =
        |x: f64| if x < 0.0 { "never".to_string() } else { f1(x) };
    for spec_str in [
        "crash@t=4,fog=1,rejoin=8",
        "slow@t=4,fog=0,factor=0.3,until=8",
        "link@t=4,src=0,dst=1,bw=0.1x,until=8",
    ] {
        let fault = FaultSpec::parse(spec_str).expect("sweep spec");
        let r = {
            let engine = ctx.engine(kind);
            run_loadtest_chaos(&g, &spec, &cluster, &opts,
                               &chaos_traffic, &omegas, engine,
                               &Recorder::disabled(),
                               std::slice::from_ref(&fault),
                               DEFAULT_TASK_DEADLINE_S)
                .expect("chaos sweep run")
        };
        let cr = r.faults.clone().expect("chaos runs report faults");
        let o = cr.outcomes.first().expect("one fault per run").clone();
        fault_table.row(vec![
            o.class.to_string(),
            f1(o.t_fault_s),
            fmt_t(o.time_to_detect_s),
            fmt_t(o.time_to_recover_s),
            f1(o.p99_delta_ms),
            pct(o.goodput_dip),
            o.shed_during.to_string(),
            o.hedges.to_string(),
        ]);
        fault_rows.push(obj(vec![
            ("class", s(o.class)),
            ("t_fault_s", num(o.t_fault_s)),
            ("time_to_detect_s", num(o.time_to_detect_s)),
            ("time_to_recover_s", num(o.time_to_recover_s)),
            ("p99_delta_ms", num(o.p99_delta_ms)),
            ("goodput_dip", num(o.goodput_dip)),
            ("shed_during", num(o.shed_during as f64)),
            ("hedges", num(o.hedges as f64)),
            ("recovered", crate::util::json::Json::Bool(o.recovered)),
        ]));
        runs.push(report_json(
            &format!("fograph-fault-{}", o.class),
            &chaos_traffic, &r));
    }
    let fault_hist_line = obj(vec![
        ("date", s(&utc_date_string())),
        ("rev", s(&git_rev())),
        ("benchmark", s("loadtest-fault-sweep")),
        ("exec", s("analytic")),
        ("rate_rps", num(cap)),
        ("duration_s", num(chaos_traffic.duration_s)),
        ("faults", arr(fault_rows)),
    ]);

    // one line per regenerated sweep, in the same committed history
    // file the kernel bench appends to
    let hist_line = obj(vec![
        ("date", s(&utc_date_string())),
        ("rev", s(&git_rev())),
        ("benchmark", s("loadtest-depth-sweep")),
        ("exec", s("measured")),
        ("kernel_threads", num(2.0)),
        ("capacity_rps", num(m_cap)),
        ("depths", arr(depth_rows)),
    ]);
    use std::io::Write as _;
    match std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("BENCH_history.jsonl")
    {
        Ok(mut f) => {
            let _ = writeln!(f, "{hist_line}");
            let _ = writeln!(f, "{fault_hist_line}");
        }
        Err(e) => eprintln!("cannot append BENCH_history.jsonl: {e}"),
    }

    let doc = doc_json(dataset, "gcn+sage", net.name(), "analytic",
                       runs, Vec::new());
    let _ = std::fs::create_dir_all(&ctx.results_dir);
    let _ = std::fs::write(
        ctx.results_dir.join("loadtest.json"),
        format!("{doc}\n"),
    );

    let fog = goodput["fograph"];
    let cloud = goodput["cloud"];
    let gain = if cloud > 0.0 {
        format!("{:.2}x", fog / cloud)
    } else {
        "inf".to_string()
    };
    let (drr_good, drr_p99) =
        lo_summary.get("drr").copied().unwrap_or((0.0, 0.0));
    let (fifo_good, fifo_p99) =
        lo_summary.get("fifo").copied().unwrap_or((0.0, 0.0));
    format!(
        "## Loadtest — sustained traffic, identical streams (SIoT, GCN, \
         WiFi, {} {} req/s × {}s, SLO {:.0} ms)\n\n{}\n\
         goodput gain fograph vs cloud: {gain} (paper's headline \
         throughput gain: 6.84x at the single-inference level).\n\n\
         ### Multi-tenant fairness — bursty gcn (weight 4) vs Poisson \
         sage (weight 1) on shared fogs (capacity probe {cap:.0} \
         req/s)\n\n{}\n\
         low-weight tenant under the burst: p99 {drr_p99:.0} ms / \
         goodput {drr_good:.1} req/s with weighted-fair DRR vs p99 \
         {fifo_p99:.0} ms / goodput {fifo_good:.1} req/s under the \
         shared-FIFO control. Per-run records (per-tenant SLO \
         summaries, Jain index, plan-cache hit counts) in \
         results/loadtest.json.\n\n\
         ### Pipelined execution — measured depth sweep at saturation \
         ({m_cap:.0} req/s, real kernels, 2 kernel threads)\n\n{}\n\
         occupancy = per-fog busy-kernel time / wall time between \
         first and last batch; stall = wall time the fabric blocked \
         on a full submission window (accounted as the pipeline_stall \
         phase, not queueing). Wall-clock numbers are host-specific; \
         each regenerated sweep appends a provenance-stamped line to \
         BENCH_history.jsonl.\n\n\
         ### Chaos — one seeded fault per class at saturation \
         ({cap:.0} req/s analytic, fault at t=4 of 12 s)\n\n{}\n\
         detect = onset to EWMA-deadline flag; recover = onset to \
         evacuation-done/rejoin/expiry (never = not within the run); \
         p99 delta and goodput dip are measured over the fault window \
         vs the rest of the run. The sweep is seed-deterministic and \
         appends a loadtest-fault-sweep line to BENCH_history.jsonl.\n",
        traffic.arrival.name(),
        traffic.rps,
        traffic.duration_s,
        traffic.slo_s * 1e3,
        table.to_markdown(),
        fair_table.to_markdown(),
        depth_table.to_markdown(),
        fault_table.to_markdown(),
    )
}
