//! `repro` — the Fograph leader CLI.
//!
//! Subcommands:
//!   dataset        generate dataset twins (.fgr) for the Python compile
//!                  path
//!   serve          run one end-to-end serving comparison on a dataset
//!   loadtest       sustained request-level load generation + online
//!                  serving
//!   bench-kernels  naive-vs-tiled kernel benchmark -> BENCH_kernels.json
//!   scale          million-vertex scale-tier sweep -> BENCH_scale.json
//!   churn          incremental-vs-rebuild churn sweep -> BENCH_churn.json
//!   exp            regenerate a paper table/figure (see experiments/)
//!   list           list datasets, artifacts and experiments

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use fograph::experiments;
use fograph::graph::delta::validate_churn_specs;
use fograph::graph::{datasets, io as gio, ChurnSpec, DatasetSpec,
                     Graph};
use fograph::net::NetKind;
use fograph::obs::{self, ClockMode, Recorder};
use fograph::profile::PerfModel;
use fograph::runtime::kernels::{shard, DEFAULT_TASK_DEADLINE_S};
use fograph::runtime::{reference, Engine, EngineKind};
use fograph::serving::{self, pipeline};
use fograph::traffic::{doc_json, fabric_json, report_json,
                       run_fabric_churn, run_loadtest_churn,
                       ArrivalKind, BatchPolicy, ChaosReport,
                       ExecMode, FabricReport, FairPolicy, FaultSpec,
                       LoadtestReport, TenantInput, TenantSpec,
                       TrafficConfig};
use fograph::util::cli::{self, Args};
use fograph::util::json::Json;

fn main() {
    // a bad FOGRAPH_MIN_ROWS_PER_SHARD must be a loud exit-2 before
    // any kernel latches the default, not a silent fallback
    if let Err(e) = shard::min_rows_per_shard_env() {
        eprintln!("{e}");
        std::process::exit(2);
    }
    // same discipline for the flight-recorder ring capacity override
    if let Err(e) = obs::trace_buf_env() {
        eprintln!("{e}");
        std::process::exit(2);
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["verbose", "keep-outputs", "gpu",
                                    "spill", "no-background-load",
                                    "smoke"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "dataset" => cmd_dataset(&args),
        "serve" => cmd_serve(&args),
        "loadtest" => cmd_loadtest(&args),
        "bench-kernels" => experiments::kernelbench::cmd(&args),
        "scale" => experiments::scale::cmd(&args),
        "churn" => experiments::churn::cmd(&args),
        "exp" => experiments::cmd_exp(&args),
        "list" => cmd_list(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "repro — Fograph reproduction CLI

USAGE:
  repro dataset  --name <siot|yelp|pems|rmat20k|...|all> [--out data]
  repro serve    --dataset <name> --model <gcn|gat|sage|astgcn>
                 [--mode cloud|single-fog|multi-fog|fograph]
                 [--net 4g|5g|wifi] [--engine pjrt|ref|csr] [--repeats N]
  repro loadtest --dataset <name> --model <gcn|gat|sage|astgcn>
                 [--mode cloud|single-fog|multi-fog|fograph|all]
                 [--net 4g|5g|wifi] [--engine pjrt|ref|csr]
                 [--exec analytic|measured] [--kernel-threads K]
                 [--pipeline-depth D]
                 [--arrival poisson|bursty|diurnal] [--rps R]
                 [--duration SECONDS] [--seed N] [--slo-ms MS]
                 [--batch-max N] [--batch-deadline-ms MS]
                 [--queue-cap N] [--spill] [--no-background-load]
                 [--scheduler-period SECONDS] [--out BENCH_loadtest.json]
                 [--tenant k=v,... (repeatable)] [--fair drr|fifo]
                 [--trace-out trace.json]
                 [--fault SPEC (repeatable)] [--task-deadline SECONDS]
                 [--churn SPEC (repeatable)]
  repro bench-kernels [--smoke] [--kernel-threads K]
                 [--out BENCH_kernels.json]
                 [--history BENCH_history.jsonl]
  repro scale    [--smoke] [--fogs N] [--fog-mem-mb MB]
                 [--out BENCH_scale.json]
                 [--history BENCH_history.jsonl]
  repro churn    [--smoke] [--fogs N]
                 [--out BENCH_churn.json]
                 [--history BENCH_history.jsonl]
  repro exp      <fig3|fig4|fig8|fig11|fig12|table4|fig13|table5|fig14|
                  fig15|fig16|fig17|fig18|loadtest|all>
                 [--engine pjrt|ref|csr]
                 [--repeats N] [--data data] [--artifacts artifacts]
  repro list     [--data data] [--artifacts artifacts]

ENGINES (see rust/src/runtime/backend.rs):
  ref   pure-Rust dense reference forward (numeric oracle)
  csr   sparse CSR aggregation, block-diagonal batched kernels
  pjrt  AOT HLO artifacts on the PJRT CPU client (needs --features pjrt)

EXEC MODES (loadtest only):
  analytic  price batches with the calibratable ω models; runs are
            bit-reproducible for a fixed seed (the default)
  measured  execute every micro-batch on the real tiled/blocked kernels
            (persistent worker pool; --kernel-threads K gives the
            largest fog a K-wide row-parallel shard group, smaller fogs
            proportionally fewer workers) and feed measured per-fog
            timings into the online profiler, so mid-run replans use
            observed costs; all models incl. astgcn.
            --pipeline-depth D (default 1) keeps up to D micro-batches
            in flight: batch N+1's collection/compression overlaps
            batch N's kernels, with layer-level double buffering inside
            the BSP plan (halo exchange overlaps straggler compute).
            Depth 1 is the serial station model with bit-identical
            reports; window-full waits are accounted as the distinct
            pipeline_stall phase and per-fog pipeline_occupancy lands
            in BENCH_loadtest.json

MULTI-TENANT (loadtest only):
  each repeatable --tenant declares one workload sharing the fog
  cluster: comma-separated key=value with keys
    name|model|dataset|arrival|rps|weight|slo-ms|seed|queue-cap
  unset keys inherit the legacy flags. Tenants get their own admission
  queues; released batches are arbitrated by deficit-round-robin
  weighted-fair queuing (--fair drr, default) so one tenant's burst
  cannot starve another's SLO, or by a shared-FIFO control (--fair
  fifo). One plan per distinct (model, dataset) is built and cached;
  all plans share one --kernel-threads worker-pool budget. Per-tenant
  p50/p95/p99/goodput/shed plus a Jain fairness index land in
  BENCH_loadtest.json.
  Example: --tenant name=hi,model=gcn,arrival=bursty,rps=300,weight=4
           --tenant name=lo,model=sage,rps=50,weight=1

OBSERVABILITY (loadtest only):
  --trace-out PATH records every request-lifecycle span (arrive →
  queue → admit/shed → batch → collect → transfer → kernel → sync →
  reply, plus scheduler replan events) into a Chrome trace-event JSON
  loadable in Perfetto (ui.perfetto.dev), one track per fog plus
  wall-clock worker tracks in measured mode, and writes a
  Prometheus-style metrics snapshot next to it (.prom). The
  phase_breakdown section of BENCH_loadtest.json is always computed
  from the same registry, tracing on or off — analytic runs stay
  bit-reproducible either way. FOGRAPH_TRACE_BUF overrides the
  per-thread span ring capacity (events; validated at startup).

CHAOS (loadtest only):
  each repeatable --fault injects one seeded, repeatable fog fault;
  the schedule is drawn from its own RNG stream so runs stay
  bit-deterministic for a fixed --seed and invariant under the order
  the faults are declared. Specs (times in seconds from run start):
    crash@t=T,fog=J[,rejoin=T2]   fog J stops replying at ~T; with
                                  rejoin= it comes back at T2
    slow@t=T,fog=J,factor=F[,until=T2]  fog J runs at speed F in (0,1]
    link@t=T,src=A,dst=B,bw=Fx[,until=T2]  inter-fog sync bandwidth
                                  drops to fraction F (e.g. bw=0.1x)
  Recovery: an EWMA straggler detector flags a fog whose tasks stop
  completing within mean + 3*dev of its history; overdue measured
  tasks are hedged to another fog (first reply wins, late loser
  discarded — outputs stay bit-identical to the fault-free path); a
  detected-dead fog's partitions are evacuated through the dual-mode
  rescheduler at the next drain barrier, accounted as the recovery
  phase. --task-deadline SECONDS bounds the per-task wait before
  hedging (and backstops a hung worker with a loud panic instead of a
  wedged run). Per fault class, time-to-detect, time-to-recover and
  SLO damage (p99 delta, goodput dip, requests shed in the hole) land
  in the faults section of BENCH_loadtest.json; fault-free runs emit
  byte-identical reports with no faults key.
  Example: --fault crash@t=5,fog=2,rejoin=15 \\
           --fault slow@t=10,fog=0,factor=0.3,until=20

STREAMING GRAPHS (loadtest only, analytic exec):
  each repeatable --churn declares one class of seeded topology
  mutation, drawn once per scheduler replan barrier and applied IN
  PLACE on an incremental CSR (tombstoned deletes, periodic
  compaction) — no full rebuild, no full repartition. Specs:
    add-edge@rate=R             insert ~R*live_vertices random edges
    del-edge@rate=R             delete ~R*live_vertices random edges
    add-vertex@rate=R[,degree=D]  add vertices with D random
                                  attachments (default 2)
    del-vertex@rate=R           remove vertices with their edges
  (rate in (0, 0.5]; one spec per op; streams are bit-deterministic
  for a fixed --seed and invariant under declaration order.)
  Only the fogs a round touches are re-grounded; boundary-only
  refinement migrates dirty-partition border vertices and the
  dual-mode scheduler consumes the remaining skew at the same
  barrier (diffusion mode). Untouched fogs keep their sub-CSRs, plan
  rows and topology fingerprints bit-for-bit — the same structures a
  from-scratch rebuild would produce, asserted by the parity suite.
  Final topology and invalidation counters land in the churn section
  of BENCH_loadtest.json; churn-free runs emit byte-identical
  reports with no churn key. Requires --scheduler-period > 0, a
  multi-fog mode, and is exclusive with --fault / --exec measured.
  Example: --churn add-edge@rate=0.01 --churn del-vertex@rate=0.002

KERNELS:
  bench-kernels measures the tiled GEMM and blocked SpMM against their
  naive baselines (GFLOP/s, effective GB/s, batched-vs-serial fog exec,
  1/2/4-worker intra-fog thread scaling, the dispatched SIMD path) and
  writes BENCH_kernels.json plus a one-line summary appended to
  BENCH_history.jsonl; --smoke runs a fast parity-checked subset for CI,
  --kernel-threads caps the scaling curve. The intra-fog shard floor
  (rows per shard) is derived per host by a one-shot micro-probe
  (channel round-trip vs. per-row kernel cost, clamped to a power of
  two in [64, 4096]); FOGRAPH_MIN_ROWS_PER_SHARD overrides it
  (validated at startup, exit 2 on junk). The active value and its
  source are recorded in BENCH_kernels.json/BENCH_history.jsonl

SCALE TIER:
  scale sweeps seeded rmat/road graphs (to 1M+ vertices; --smoke runs
  a small sweep for CI) through streamed one-fog-at-a-time grounding,
  a bounded per-fog feature store that spills cold blocks through the
  quantize-off LZ4 pipeline (--fog-mem-mb MB; default = 3/4 of the
  largest point's per-fog features so the top of the sweep must
  spill), and the indexed collection path. Gates: streamed/materialized
  exchange-plan parity, streamed peak logical bytes below
  materialize-all, zero bit-mismatches on spill-rehydrate access, and
  spills > 0 whenever the budget is infeasible. Writes BENCH_scale.json
  (vertices/sec/fog, grounding times, spill counters, peak_rss_bytes)
  and appends a provenance line to BENCH_history.jsonl

CHURN TIER:
  churn sweeps seeded rmat/road graphs under a mixed mutation trace
  and races the incremental topology engine (in-place CSR deltas +
  partition-scoped re-grounding) against a full rebuild + multilevel
  repartition + re-ground at every round (--smoke runs a small sweep
  for CI). Gates: mutated-incrementally == rebuilt-from-scratch
  bit-for-bit (sub-CSRs, exchange plan, served outputs) at every
  round, zero re-grounding for untouched partitions in the trickle
  phase, and >= 10x delta-apply speedup over rebuild at ~1% churn on
  the top tier (non-smoke). Writes BENCH_churn.json and appends a
  provenance line to BENCH_history.jsonl"
    );
}

/// Validated (spec, graph) for a `--dataset` flag, or a CLI error.
fn resolve_dataset(args: &Args) -> Result<(DatasetSpec, Graph), String> {
    let data_dir = PathBuf::from(args.get_or("data", "data"));
    let ds = args.get_or("dataset", "siot");
    let spec = datasets::spec_by_name(ds)
        .ok_or_else(|| format!("unknown dataset {ds}"))?;
    let g = datasets::load_or_generate(&data_dir, ds)
        .map_err(|e| e.to_string())?;
    Ok((spec, g))
}

fn resolve_model(args: &Args) -> Result<String, String> {
    let model = args.get_or("model", "gcn");
    if reference::known_model(model) {
        Ok(model.to_string())
    } else {
        Err(format!(
            "unknown model {model} (expected one of {})",
            reference::KNOWN_MODELS.join("|")
        ))
    }
}

fn resolve_net(args: &Args) -> Result<NetKind, String> {
    let net = args.get_or("net", "wifi");
    NetKind::parse(net).ok_or_else(|| format!("unknown net {net}"))
}

/// Validated `--kernel-threads` (default 1): worker-group width the
/// largest fog partition gets. 0, non-numeric and absurd values are
/// CLI errors (exit code 2), not silent fallbacks.
pub fn resolve_kernel_threads(args: &Args) -> Result<usize, String> {
    fograph::util::cli::parse_kernel_threads(args)
}

/// Validated (spec, graph, model, net) shared by serve and loadtest;
/// prints every error and yields the CLI exit code on failure.
fn resolve_run_inputs(args: &Args)
                      -> Result<(DatasetSpec, Graph, String, NetKind), i32> {
    match (resolve_dataset(args), resolve_model(args), resolve_net(args)) {
        (Ok((spec, g)), Ok(model), Ok(net)) => Ok((spec, g, model, net)),
        (d, m, n) => {
            for e in [d.err(), m.err(), n.err()].into_iter().flatten() {
                eprintln!("{e}");
            }
            Err(2)
        }
    }
}

fn make_engine(args: &Args) -> Engine {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    // a std-only build has no PJRT client; don't route every default
    // run through a doomed init + fallback warning
    let default_engine =
        if cfg!(feature = "pjrt") { "pjrt" } else { "ref" };
    let engine_kind = match args.get_or("engine", default_engine) {
        "ref" | "reference" => EngineKind::Reference,
        "csr" => EngineKind::Csr,
        _ => EngineKind::Pjrt,
    };
    match Engine::new(engine_kind, &artifacts) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine init failed ({e}); falling back to reference");
            Engine::new(EngineKind::Reference, &artifacts).unwrap()
        }
    }
}

fn cmd_dataset(args: &Args) -> i32 {
    let out = PathBuf::from(args.get_or("out", "data"));
    std::fs::create_dir_all(&out).expect("create data dir");
    let name = args.get_or("name", "all");
    let names: Vec<&str> = if name == "all" {
        datasets::all_specs().iter().map(|s| s.name).collect()
    } else {
        name.split(',').collect()
    };
    for n in names {
        let spec = match datasets::spec_by_name(n) {
            Some(s) => s,
            None => {
                eprintln!("unknown dataset {n}");
                return 2;
            }
        };
        let path = out.join(format!("{n}.fgr"));
        if path.exists() {
            println!("{n}: already at {}", path.display());
            continue;
        }
        let t = fograph::obs::clock::Stopwatch::start();
        let g = match datasets::generate(n) {
            Ok(g) => g,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        gio::write_fgr(&path, &g).expect("write .fgr");
        println!(
            "{n}: V={} E={} F={} -> {} ({:.1}s)",
            g.num_vertices(),
            g.undirected_edges(),
            spec.feature_dim,
            path.display(),
            t.elapsed_s()
        );
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let mode = args.get_or("mode", "fograph");
    let repeats = args.get_usize("repeats", 3);
    let (spec, g, model, net) = match resolve_run_inputs(args) {
        Ok(x) => x,
        Err(code) => return code,
    };
    let Some((cluster, opts)) = pipeline::mode_setup(mode, &model, net, &g)
    else {
        eprintln!("unknown mode {mode}");
        return 2;
    };
    let mut engine = make_engine(args);
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
    let mut reports = Vec::new();
    for _ in 0..repeats {
        match serving::serve(&g, &spec, &cluster, &opts, &omegas,
                             &mut engine) {
            Ok(r) => reports.push(r),
            Err(e) => {
                eprintln!("serving failed: {e}");
                return 1;
            }
        }
    }
    let r = fograph::serving::metrics::average(reports);
    println!("mode={mode} dataset={} model={model} net={}", spec.name,
             net.name());
    println!(
        "  latency   {:.4} s  (collect {:.4} + exec {:.4} + sync {:.4} + unpack {:.4})",
        r.total_s, r.collection_s, r.execution_s, r.sync_s, r.unpack_s
    );
    println!("  throughput {:.2} inf/s", r.throughput);
    println!(
        "  wire {:.2} MB (raw {:.2} MB, ratio {:.3})",
        r.wire_bytes as f64 / 1e6,
        r.raw_bytes as f64 / 1e6,
        r.wire_bytes as f64 / r.raw_bytes.max(1) as f64
    );
    if !engine.synthetic_weights.is_empty() {
        eprintln!(
            "  note: synthetic weights used for {:?} (run `make artifacts`)",
            engine.synthetic_weights
        );
    }
    0
}

fn cmd_loadtest(args: &Args) -> i32 {
    // validate the cheap flags before paying for dataset generation
    let arrival_name = args.get_or("arrival", "poisson");
    let Some(arrival) = ArrivalKind::parse(arrival_name) else {
        eprintln!("unknown arrival process {arrival_name}");
        return 2;
    };
    let exec_name = args.get_or("exec", "analytic");
    let Some(exec) = ExecMode::parse(exec_name) else {
        eprintln!("unknown exec mode {exec_name} \
                   (expected analytic|measured)");
        return 2;
    };
    let kernel_threads = match resolve_kernel_threads(args) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let pipeline_depth =
        match fograph::util::cli::parse_pipeline_depth(args) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
    let task_deadline_s = match fograph::util::cli::parse_task_deadline(
        args, DEFAULT_TASK_DEADLINE_S) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // repeatable --fault specs: grammar and range errors are a loud
    // exit 2 before any dataset work. A bare `--fault` (value missing
    // or eaten by the shell) parses as a switch — reject it too.
    // Fog-id / run-end validation needs the mode's cluster size and
    // happens below, once per mode, with the same exit code.
    if args.has("fault") {
        eprintln!(
            "--fault requires a spec value (e.g. --fault \
             crash@t=5,fog=2,rejoin=15)"
        );
        return 2;
    }
    let mut faults: Vec<FaultSpec> = Vec::new();
    for raw in args.get_all("fault") {
        match FaultSpec::parse(raw) {
            Ok(f) => faults.push(f),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    // repeatable --churn specs: same loud exit-2 treatment as --fault
    // (bare flag, grammar/range junk, duplicate op declarations), all
    // before any dataset work
    if args.has("churn") {
        eprintln!(
            "--churn requires a spec value (e.g. --churn \
             add-edge@rate=0.01)"
        );
        return 2;
    }
    let mut churn: Vec<ChurnSpec> = Vec::new();
    for raw in args.get_all("churn") {
        match ChurnSpec::parse(raw) {
            Ok(c) => churn.push(c),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    if let Err(e) = validate_churn_specs(&churn) {
        eprintln!("{e}");
        return 2;
    }
    if !churn.is_empty() && !faults.is_empty() {
        eprintln!(
            "--churn cannot be combined with --fault: the chaos \
             evacuation replans against the static grounding graph"
        );
        return 2;
    }
    if !churn.is_empty() && exec == ExecMode::Measured {
        eprintln!(
            "--churn requires --exec analytic: measured plans pin a \
             fixed topology in the worker pool"
        );
        return 2;
    }
    let traffic = TrafficConfig {
        arrival,
        rps: args.get_f64("rps", 100.0),
        duration_s: args.get_f64("duration", 30.0),
        seed: args.get_u64("seed", 0xF06),
        slo_s: args.get_f64("slo-ms", 1000.0) / 1e3,
        batch: BatchPolicy {
            max_batch: args.get_usize("batch-max", 32).max(1),
            max_delay_s: args.get_f64("batch-deadline-ms", 20.0) / 1e3,
        },
        queue_cap: args.get_usize("queue-cap", 64),
        spill: args.has("spill"),
        scheduler_period_s: args.get_f64("scheduler-period", 5.0),
        background_load: !args.has("no-background-load"),
        exec,
        kernel_threads,
        pipeline_depth,
    };
    let positive = |x: f64| x.is_finite() && x > 0.0;
    if !positive(traffic.rps) || !positive(traffic.duration_s) {
        eprintln!("--rps and --duration must be positive finite numbers");
        return 2;
    }
    if !churn.is_empty() && traffic.scheduler_period_s <= 0.0 {
        eprintln!(
            "--churn requires a positive --scheduler-period: topology \
             deltas apply at replan barriers"
        );
        return 2;
    }
    if !traffic.batch.max_delay_s.is_finite()
        || traffic.batch.max_delay_s < 0.0
        || !positive(traffic.slo_s)
    {
        eprintln!(
            "--batch-deadline-ms must be >= 0 and --slo-ms positive"
        );
        return 2;
    }
    let fair_name = args.get_or("fair", "drr");
    let Some(fair) = FairPolicy::parse(fair_name) else {
        eprintln!("unknown fair policy {fair_name} (expected drr|fifo)");
        return 2;
    };
    // --trace-out preflight: a bare flag (value eaten by the shell)
    // or an unwritable path must be a loud exit 2 before any dataset
    // work, not a silent no-trace run or a failure after the run
    if args.has("trace-out") {
        eprintln!(
            "--trace-out requires a file path (e.g. --trace-out \
             trace.json)"
        );
        return 2;
    }
    let trace_out = args.get("trace-out").map(str::to_string);
    if let Some(p) = &trace_out {
        if let Err(e) = cli::probe_writable(p) {
            eprintln!("--trace-out: {e}");
            return 2;
        }
    }
    let rec = if trace_out.is_some() {
        Recorder::enabled(ClockMode::Virtual)
    } else {
        Recorder::disabled()
    };
    let mode = args.get_or("mode", "fograph");
    let modes: Vec<&str> = if mode == "all" {
        pipeline::MODES.to_vec()
    } else if pipeline::MODES.contains(&mode) {
        vec![mode]
    } else {
        eprintln!("unknown mode {mode}");
        return 2;
    };
    // repeatable --tenant flags switch the run onto the multi-tenant
    // fabric; parse (and reject) them before paying for datasets. A
    // bare `--tenant` (value missing or eaten by the shell) parses as
    // a switch — that must be a loud error, not a silent fall-back to
    // the single-tenant path
    if args.has("tenant") {
        eprintln!(
            "--tenant requires a spec value (e.g. --tenant \
             model=gcn,rps=100,weight=2)"
        );
        return 2;
    }
    let tenant_flags = args.get_all("tenant");
    if !tenant_flags.is_empty() {
        let mut specs = Vec::new();
        for raw in &tenant_flags {
            match TenantSpec::parse(raw) {
                Ok(s) => specs.push(s),
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            }
        }
        return cmd_loadtest_fabric(args, &traffic, fair, &modes,
                                   &specs, &rec,
                                   trace_out.as_deref(), &faults,
                                   task_deadline_s, &churn);
    }
    let (spec, g, model, net) = match resolve_run_inputs(args) {
        Ok(x) => x,
        Err(code) => return code,
    };
    let mut engine = make_engine(args);
    let mut runs: Vec<Json> = Vec::new();
    for m in modes {
        let Some((cluster, opts)) =
            pipeline::mode_setup(m, &model, net, &g)
        else {
            eprintln!("unknown mode {m}");
            return 2;
        };
        for f in &faults {
            if let Err(e) = f.validate(cluster.len(),
                                       traffic.duration_s) {
                eprintln!("{e}");
                return 2;
            }
        }
        let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
        let r = match run_loadtest_churn(&g, &spec, &cluster, &opts,
                                         &traffic, &omegas,
                                         &mut engine, &rec, &faults,
                                         task_deadline_s, &churn) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("loadtest failed: {e}");
                return 1;
            }
        };
        print_loadtest(m, &spec, &model, net, &traffic, &r);
        print_faults(&r.faults);
        print_churn(&r.churn);
        runs.push(report_json(m, &traffic, &r));
    }
    let out = args.get_or("out", "BENCH_loadtest.json");
    let doc_engine = match traffic.exec {
        ExecMode::Measured => "csr-batched",
        ExecMode::Analytic => engine.backend_name(),
    };
    let doc = doc_json(spec.name, &model, net.name(), doc_engine, runs,
                       Vec::new());
    match std::fs::write(out, format!("{doc}\n")) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => {
            eprintln!("cannot write {out}: {e}");
            return 1;
        }
    }
    if let Some(path) = &trace_out {
        let names = vec!["default".to_string()];
        match obs::write_trace_files(&rec, &names, path) {
            Ok(prom) => println!("wrote {path} (+ {prom})"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                return 1;
            }
        }
    }
    0
}

/// The multi-tenant loadtest path: resolve every `--tenant` spec
/// against the legacy flags, load each distinct dataset once, and run
/// the serving fabric per mode.
#[allow(clippy::too_many_arguments)]
fn cmd_loadtest_fabric(args: &Args, traffic: &TrafficConfig,
                       fair: FairPolicy, modes: &[&str],
                       specs: &[TenantSpec], rec: &Arc<Recorder>,
                       trace_out: Option<&str>, faults: &[FaultSpec],
                       task_deadline_s: f64,
                       churn: &[ChurnSpec]) -> i32 {
    let default_model = args.get_or("model", "gcn").to_string();
    let default_dataset = args.get_or("dataset", "siot").to_string();
    let tenants: Vec<fograph::traffic::Tenant> = specs
        .iter()
        .map(|s| s.resolve(traffic, &default_model, &default_dataset))
        .collect();
    for t in &tenants {
        if !reference::known_model(&t.model) {
            eprintln!(
                "tenant {}: unknown model {} (expected one of {})",
                t.name,
                t.model,
                reference::KNOWN_MODELS.join("|")
            );
            return 2;
        }
    }
    let mut names: Vec<&str> =
        tenants.iter().map(|t| t.name.as_str()).collect();
    names.sort_unstable();
    for w in names.windows(2) {
        if w[0] == w[1] {
            eprintln!(
                "duplicate tenant name {:?}: set name=... to \
                 distinguish tenants sharing a (model, dataset)",
                w[0]
            );
            return 2;
        }
    }
    let net = match resolve_net(args) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // one load per distinct dataset, shared by its tenants
    let data_dir = PathBuf::from(args.get_or("data", "data"));
    let mut packs: BTreeMap<String, (DatasetSpec, Graph)> =
        BTreeMap::new();
    for t in &tenants {
        if packs.contains_key(&t.dataset) {
            continue;
        }
        let Some(spec) = datasets::spec_by_name(&t.dataset) else {
            eprintln!("tenant {}: unknown dataset {}", t.name,
                      t.dataset);
            return 2;
        };
        match datasets::load_or_generate(&data_dir, &t.dataset) {
            Ok(g) => {
                packs.insert(t.dataset.clone(), (spec, g));
            }
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let mut engine = make_engine(args);
    let mut runs: Vec<Json> = Vec::new();
    let mut trace_names: Vec<String> = Vec::new();
    for m in modes {
        let mut inputs: Vec<TenantInput<'_>> = Vec::new();
        let mut cluster = None;
        for t in &tenants {
            let (spec, g) = &packs[&t.dataset];
            let Some((cl, opts)) =
                pipeline::mode_setup(m, &t.model, net, g)
            else {
                eprintln!("unknown mode {m}");
                return 2;
            };
            let omegas =
                vec![PerfModel::uncalibrated_for(&t.model); cl.len()];
            if cluster.is_none() {
                // the cluster is a property of (mode, net), identical
                // across tenants
                cluster = Some(cl);
            }
            inputs.push(TenantInput {
                tenant: t.clone(),
                g,
                spec: *spec,
                opts,
                omegas,
            });
        }
        let cluster = cluster.expect("at least one tenant");
        for f in faults {
            if let Err(e) = f.validate(cluster.len(),
                                       traffic.duration_s) {
                eprintln!("{e}");
                return 2;
            }
        }
        let fr = match run_fabric_churn(&cluster, inputs, traffic,
                                        fair, &mut engine, rec,
                                        faults, task_deadline_s,
                                        churn) {
            Ok(fr) => fr,
            Err(e) => {
                eprintln!("loadtest failed: {e}");
                return 1;
            }
        };
        if trace_names.is_empty() {
            trace_names =
                fr.tenants.iter().map(|t| t.name.clone()).collect();
        }
        print_fabric(m, net, traffic, &fr);
        print_faults(&fr.aggregate.faults);
        print_churn(&fr.aggregate.churn);
        runs.push(fabric_json(m, traffic, &fr));
    }
    let out = args.get_or("out", "BENCH_loadtest.json");
    let doc_engine = match traffic.exec {
        ExecMode::Measured => "csr-batched",
        ExecMode::Analytic => engine.backend_name(),
    };
    // BTreeMap keys: already unique and sorted
    let ds: Vec<&str> =
        packs.keys().map(|k| k.as_str()).collect();
    let mut ms: Vec<&str> =
        tenants.iter().map(|t| t.model.as_str()).collect();
    ms.sort_unstable();
    ms.dedup();
    let doc = doc_json(&ds.join("+"), &ms.join("+"), net.name(),
                       doc_engine, runs, Vec::new());
    match std::fs::write(out, format!("{doc}\n")) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => {
            eprintln!("cannot write {out}: {e}");
            return 1;
        }
    }
    if let Some(path) = trace_out {
        match obs::write_trace_files(rec, &trace_names, path) {
            Ok(prom) => println!("wrote {path} (+ {prom})"),
            Err(e) => {
                eprintln!("cannot write {path}: {e}");
                return 1;
            }
        }
    }
    0
}

/// Per-run console summary for a fabric run: the aggregate line plus
/// one line per tenant and the fairness/plan-cache accounting.
fn print_fabric(mode: &str, net: NetKind, traffic: &TrafficConfig,
                fr: &FabricReport) {
    let agg = &fr.aggregate.slo;
    println!(
        "mode={mode} net={} tenants={} fair={} duration={}s seed={} \
         exec={}",
        net.name(),
        fr.tenants.len(),
        fr.fair.name(),
        traffic.duration_s,
        traffic.seed,
        fr.aggregate.exec_mode.name(),
    );
    if agg.oom {
        println!("  OOM: a placement exceeds fog memory; run aborted");
        return;
    }
    for t in &fr.tenants {
        println!(
            "  tenant {:<12} {}/{} {} rps w={} | p50 {:.1} p95 {:.1} \
             p99 {:.1} ms (SLO {:.0}) | goodput {:.2}/s | {}/{} \
             offered, {:.1}% shed, {} spilled",
            t.name,
            t.model,
            t.dataset,
            t.rps,
            t.weight,
            t.slo.latency.p50_s * 1e3,
            t.slo.latency.p95_s * 1e3,
            t.slo.latency.p99_s * 1e3,
            t.slo.slo_s * 1e3,
            t.slo.goodput_rps,
            t.slo.within_slo,
            t.slo.offered,
            t.slo.shed_rate() * 100.0,
            t.slo.spilled,
        );
    }
    println!(
        "  fairness   jain={:.4} (weight-normalized goodput); \
         aggregate goodput {:.2}/s, {} batches, {} diffusions, {} \
         replans",
        fr.fairness_jain,
        agg.goodput_rps,
        agg.batches,
        agg.diffusions,
        agg.replans,
    );
    for e in &fr.plan_cache {
        println!(
            "  plan-cache {}/{}: {} build, {} hits, {} rebuilds",
            e.model, e.dataset, e.builds, e.hits, e.rebuilds
        );
    }
    if !fr.aggregate.bucket_host_ms.is_empty() {
        let buckets: Vec<String> = fr
            .aggregate
            .bucket_host_ms
            .iter()
            .map(|row| {
                format!("b{}: {:.2} ms x{}", row.bucket,
                        row.mean_host_ms, row.batches)
            })
            .collect();
        println!("  measured   per-bucket batch host time: {}",
                 buckets.join(", "));
    }
}

/// Console summary of a chaos run's `faults` section: the hedge
/// accounting plus one line per injected fault. No-op (no output at
/// all) for fault-free runs.
fn print_faults(faults: &Option<ChaosReport>) {
    let Some(c) = faults else { return };
    println!(
        "  chaos      task-deadline {:.0} ms; hedges {} won, {} wasted",
        c.task_deadline_s * 1e3,
        c.hedge_wins,
        c.hedge_waste
    );
    let fmt_t = |t: f64| {
        if t < 0.0 {
            "never".to_string()
        } else {
            format!("{:.2}s", t)
        }
    };
    for o in &c.outcomes {
        let target = if o.peer >= 0 {
            format!("link {}->{}", o.fog, o.peer)
        } else {
            format!("fog {}", o.fog)
        };
        println!(
            "    {:<5} {} @t={:.2}s: detect {} recover {} ({}) | \
             p99 {:+.1} ms, goodput dip {:.0}%, {} shed, {} hedges",
            o.class,
            target,
            o.t_fault_s,
            fmt_t(o.time_to_detect_s),
            fmt_t(o.time_to_recover_s),
            if o.recovered { "recovered" } else { "unrecovered" },
            o.p99_delta_ms,
            o.goodput_dip * 100.0,
            o.shed_during,
            o.hedges
        );
    }
}

/// Console summary of a churn run's `churn` section. No-op (no output
/// at all) for static-topology runs.
fn print_churn(churn: &Option<fograph::graph::ChurnSummary>) {
    let Some(c) = churn else { return };
    let st = &c.stats;
    println!(
        "  churn      {} rounds, {} deltas, {} migrations -> final \
         {} live vertices / {} edges",
        st.rounds, st.deltas_applied, st.migrations,
        c.final_live_vertices, c.final_edges
    );
    println!(
        "             invalidation: {} fogs re-grounded, {} \
         degree-patched, {} preserved bit-for-bit ({} partial \
         rounds, {} plan rows reindexed, {} compactions)",
        st.fogs_reground, st.fogs_degree_patched, st.fogs_preserved,
        st.partial_rounds, st.plan_rows_reindexed, st.compactions
    );
}

fn print_loadtest(mode: &str, spec: &DatasetSpec, model: &str,
                  net: NetKind, traffic: &TrafficConfig,
                  r: &LoadtestReport) {
    let slo = &r.slo;
    println!(
        "mode={mode} dataset={} model={model} net={} arrival={} \
         rps={} duration={}s seed={}",
        spec.name,
        net.name(),
        traffic.arrival.name(),
        traffic.rps,
        traffic.duration_s,
        traffic.seed
    );
    if slo.oom {
        println!("  OOM: placement exceeds fog memory; all load shed");
        return;
    }
    println!(
        "  latency    p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms  \
         (SLO {:.0} ms)",
        slo.latency.p50_s * 1e3,
        slo.latency.p95_s * 1e3,
        slo.latency.p99_s * 1e3,
        slo.slo_s * 1e3
    );
    println!(
        "  goodput    {:.2} req/s within SLO ({}/{} offered, {:.1}% shed, \
         {} spilled)",
        slo.goodput_rps,
        slo.within_slo,
        slo.offered,
        slo.shed_rate() * 100.0,
        slo.spilled
    );
    println!(
        "  batching   {} batches, mean {:.1} req/batch, exec util {:.0}%",
        slo.batches,
        slo.mean_batch,
        r.exec_utilization * 100.0
    );
    println!(
        "  scheduler  {} diffusions, {} replans; queue depth mean {:.1} \
         max {} (skew {:.2})",
        slo.diffusions,
        slo.replans,
        r.queue_len_mean,
        r.queue_len_max,
        slo.queue.mean_skew()
    );
    println!(
        "  exec       {} ({}, kernel_threads={}, simd={})",
        r.exec_mode.name(),
        r.engine,
        r.kernel_threads,
        r.simd
    );
    if !r.bucket_host_ms.is_empty() {
        let buckets: Vec<String> = r
            .bucket_host_ms
            .iter()
            .map(|row| {
                format!(
                    "b{}: {:.2} ms (+{:.3} ms queue) x{}",
                    row.bucket,
                    row.mean_host_ms,
                    row.mean_queue_wait_ms,
                    row.batches
                )
            })
            .collect();
        println!("  measured   per-bucket batch host time: {}",
                 buckets.join(", "));
    }
    if let Some(p) = &r.pipeline {
        let occ: Vec<String> =
            p.occupancy.iter().map(|o| format!("{o:.2}")).collect();
        println!(
            "  pipeline   depth={} occupancy=[{}] stall={:.1} ms",
            p.depth,
            occ.join(", "),
            p.stall_s * 1e3
        );
    }
}

fn cmd_list(args: &Args) -> i32 {
    let data_dir = PathBuf::from(args.get_or("data", "data"));
    println!("datasets (Table III twins):");
    for s in datasets::all_specs() {
        let status = if data_dir.join(format!("{}.fgr", s.name)).exists() {
            "generated"
        } else {
            "not generated"
        };
        println!(
            "  {:<9} V={:<7} E={:<8} F={:<3} C={} [{status}]",
            s.name, s.vertices, s.edges, s.feature_dim, s.classes
        );
    }
    let art = Path::new(args.get_or("artifacts", "artifacts"));
    match fograph::runtime::Manifest::load(art) {
        Ok(m) => println!("artifacts: {} lowered modules in {}",
                          m.artifacts.len(), art.display()),
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    println!("experiments: {}", experiments::available().join(", "));
    0
}
