//! `repro` — the Fograph leader CLI.
//!
//! Subcommands:
//!   dataset   generate dataset twins (.fgr) for the Python compile path
//!   serve     run one end-to-end serving comparison on a dataset
//!   exp       regenerate a paper table/figure (see experiments/)
//!   list      list datasets, artifacts and experiments

use std::path::{Path, PathBuf};

use fograph::compress::Codec;
use fograph::experiments;
use fograph::fog::Cluster;
use fograph::graph::{datasets, io as gio};
use fograph::net::NetKind;
use fograph::profile::PerfModel;
use fograph::runtime::{Engine, EngineKind};
use fograph::serving::{self, Placement, ServeOpts};
use fograph::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["verbose", "keep-outputs", "gpu"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "dataset" => cmd_dataset(&args),
        "serve" => cmd_serve(&args),
        "exp" => experiments::cmd_exp(&args),
        "list" => cmd_list(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "repro — Fograph reproduction CLI

USAGE:
  repro dataset --name <siot|yelp|pems|rmat20k|...|all> [--out data]
  repro serve   --dataset <name> --model <gcn|gat|sage|astgcn>
                [--mode cloud|single-fog|multi-fog|fograph]
                [--net 4g|5g|wifi] [--engine pjrt|ref] [--repeats N]
  repro exp     <fig3|fig4|fig8|fig11|fig12|table4|fig13|table5|fig14|
                 fig15|fig16|fig17|fig18|all> [--engine pjrt|ref]
                [--repeats N] [--data data] [--artifacts artifacts]
  repro list    [--data data] [--artifacts artifacts]"
    );
}

fn cmd_dataset(args: &Args) -> i32 {
    let out = PathBuf::from(args.get_or("out", "data"));
    std::fs::create_dir_all(&out).expect("create data dir");
    let name = args.get_or("name", "all");
    let names: Vec<&str> = if name == "all" {
        datasets::all_specs().iter().map(|s| s.name).collect()
    } else {
        name.split(',').collect()
    };
    for n in names {
        let spec = match datasets::spec_by_name(n) {
            Some(s) => s,
            None => {
                eprintln!("unknown dataset {n}");
                return 2;
            }
        };
        let path = out.join(format!("{n}.fgr"));
        if path.exists() {
            println!("{n}: already at {}", path.display());
            continue;
        }
        let t = std::time::Instant::now();
        let g = datasets::generate(n);
        gio::write_fgr(&path, &g).expect("write .fgr");
        println!(
            "{n}: V={} E={} F={} -> {} ({:.1}s)",
            g.num_vertices(),
            g.undirected_edges(),
            spec.feature_dim,
            path.display(),
            t.elapsed().as_secs_f64()
        );
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let data_dir = PathBuf::from(args.get_or("data", "data"));
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let ds = args.get_or("dataset", "siot");
    let model = args.get_or("model", "gcn");
    let mode = args.get_or("mode", "fograph");
    let net = NetKind::parse(args.get_or("net", "wifi")).expect("bad --net");
    let repeats = args.get_usize("repeats", 3);
    let engine_kind = match args.get_or("engine", "pjrt") {
        "ref" | "reference" => EngineKind::Reference,
        _ => EngineKind::Pjrt,
    };
    let spec = datasets::spec_by_name(ds).expect("unknown dataset");
    let g = datasets::load_or_generate(&data_dir, ds);
    let mut engine = match Engine::new(engine_kind, &artifacts) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine init failed ({e}); falling back to reference");
            Engine::new(EngineKind::Reference, &artifacts).unwrap()
        }
    };

    let (cluster, opts) = match mode {
        "cloud" => (
            Cluster::cloud(net),
            ServeOpts {
                wan: true,
                ..ServeOpts::new(model, Placement::SingleNode(0),
                                 Codec::None)
            },
        ),
        "single-fog" => {
            let c = Cluster::testbed(net);
            let p = c.most_powerful();
            (c, ServeOpts::new(model, Placement::SingleNode(p),
                               Codec::None))
        }
        "multi-fog" => (
            Cluster::testbed(net),
            ServeOpts::new(model, Placement::MetisRandom(1), Codec::None),
        ),
        "fograph" => (
            Cluster::testbed(net),
            ServeOpts::new(model, Placement::Iep, ServeOpts::co_codec(&g)),
        ),
        other => {
            eprintln!("unknown mode {other}");
            return 2;
        }
    };
    let omegas = vec![PerfModel::uncalibrated(); cluster.len()];
    let mut reports = Vec::new();
    for _ in 0..repeats {
        match serving::serve(&g, &spec, &cluster, &opts, &omegas,
                             &mut engine) {
            Ok(r) => reports.push(r),
            Err(e) => {
                eprintln!("serving failed: {e}");
                return 1;
            }
        }
    }
    let r = fograph::serving::metrics::average(reports);
    println!("mode={mode} dataset={ds} model={model} net={}", net.name());
    println!(
        "  latency   {:.4} s  (collect {:.4} + exec {:.4} + sync {:.4} + unpack {:.4})",
        r.total_s, r.collection_s, r.execution_s, r.sync_s, r.unpack_s
    );
    println!("  throughput {:.2} inf/s", r.throughput);
    println!(
        "  wire {:.2} MB (raw {:.2} MB, ratio {:.3})",
        r.wire_bytes as f64 / 1e6,
        r.raw_bytes as f64 / 1e6,
        r.wire_bytes as f64 / r.raw_bytes.max(1) as f64
    );
    if !engine.synthetic_weights.is_empty() {
        eprintln!(
            "  note: synthetic weights used for {:?} (run `make artifacts`)",
            engine.synthetic_weights
        );
    }
    0
}

fn cmd_list(args: &Args) -> i32 {
    let data_dir = PathBuf::from(args.get_or("data", "data"));
    println!("datasets (Table III twins):");
    for s in datasets::all_specs() {
        let status = if data_dir.join(format!("{}.fgr", s.name)).exists() {
            "generated"
        } else {
            "not generated"
        };
        println!(
            "  {:<9} V={:<7} E={:<8} F={:<3} C={} [{status}]",
            s.name, s.vertices, s.edges, s.feature_dim, s.classes
        );
    }
    let art = Path::new(args.get_or("artifacts", "artifacts"));
    match fograph::runtime::Manifest::load(art) {
        Ok(m) => println!("artifacts: {} lowered modules in {}",
                          m.artifacts.len(), art.display()),
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    println!("experiments: {}", experiments::available().join(", "));
    0
}
