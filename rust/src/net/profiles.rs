//! Access-technology parameters. Calibrated (see net::tests) so that the
//! SIoT upload scenario reproduces §II-C's measured cloud→fog collection
//! reductions (64% on 4G, 67% on 5G, 61% on WiFi) — the WAN backhaul is
//! the cloud bottleneck, the shared access point the fog-side one.

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NetKind {
    Cell4G,
    Cell5G,
    Wifi,
}

impl NetKind {
    pub fn parse(s: &str) -> Option<NetKind> {
        match s.to_ascii_lowercase().as_str() {
            "4g" => Some(NetKind::Cell4G),
            "5g" => Some(NetKind::Cell5G),
            "wifi" => Some(NetKind::Wifi),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NetKind::Cell4G => "4G",
            NetKind::Cell5G => "5G",
            NetKind::Wifi => "WiFi",
        }
    }

    pub fn all() -> [NetKind; 3] {
        [NetKind::Cell4G, NetKind::Cell5G, NetKind::Wifi]
    }
}

#[derive(Clone, Copy, Debug)]
pub struct NetProfile {
    pub kind: NetKind,
    /// Single-device uplink (Mbps).
    pub device_uplink_mbps: f64,
    /// Aggregate capacity of one fog-side access point (Mbps).
    pub ap_capacity_mbps: f64,
    /// Long-haul WAN capacity toward the cloud region (Mbps).
    pub wan_capacity_mbps: f64,
    /// LAN round-trip (device ↔ fog).
    pub lan_rtt_s: f64,
    /// WAN round-trip (device ↔ cloud, ~200 km + congestion).
    pub wan_rtt_s: f64,
    /// Inter-fog LAN bandwidth for BSP synchronization (Mbps).
    pub interfog_mbps: f64,
    /// Inter-fog LAN round-trip.
    pub interfog_rtt_s: f64,
}

impl NetProfile {
    pub fn get(kind: NetKind) -> NetProfile {
        match kind {
            NetKind::Cell4G => NetProfile {
                kind,
                device_uplink_mbps: 12.0,
                ap_capacity_mbps: 48.0,
                wan_capacity_mbps: 22.0,
                lan_rtt_s: 0.012,
                wan_rtt_s: 0.055,
                interfog_mbps: 400.0,
                interfog_rtt_s: 0.004,
            },
            NetKind::Cell5G => NetProfile {
                kind,
                device_uplink_mbps: 45.0,
                ap_capacity_mbps: 155.0,
                wan_capacity_mbps: 67.0,
                lan_rtt_s: 0.008,
                wan_rtt_s: 0.048,
                interfog_mbps: 900.0,
                interfog_rtt_s: 0.003,
            },
            NetKind::Wifi => NetProfile {
                kind,
                device_uplink_mbps: 30.0,
                ap_capacity_mbps: 78.0,
                wan_capacity_mbps: 40.0,
                lan_rtt_s: 0.006,
                wan_rtt_s: 0.050,
                interfog_mbps: 900.0,
                interfog_rtt_s: 0.002,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_roundtrip() {
        for k in NetKind::all() {
            assert_eq!(NetKind::parse(k.name()), Some(k));
        }
        assert_eq!(NetKind::parse("6g"), None);
    }

    #[test]
    fn faster_tech_has_more_capacity() {
        let g4 = NetProfile::get(NetKind::Cell4G);
        let g5 = NetProfile::get(NetKind::Cell5G);
        assert!(g5.device_uplink_mbps > g4.device_uplink_mbps);
        assert!(g5.ap_capacity_mbps > g4.ap_capacity_mbps);
        assert!(g5.wan_capacity_mbps > g4.wan_capacity_mbps);
    }
}
