//! Network substrate: analytic transfer-time models for the three access
//! technologies the paper evaluates (4G, 5G-NSA, WiFi) plus the WAN path
//! to the cloud — calibrated so the cloud-vs-fog data-collection ratios
//! match the paper's §II-C measurements (64%/67%/61% collection-latency
//! reduction for 4G/5G/WiFi).

pub mod profiles;

pub use profiles::{NetProfile, NetKind};

/// Transfer time of `bytes` over a link of `mbps` with `rtt_s` setup
/// latency (payloads here are ≫ MTU, so a single-RTT model suffices).
pub fn transfer_time_s(bytes: usize, mbps: f64, rtt_s: f64) -> f64 {
    debug_assert!(mbps > 0.0);
    rtt_s + (bytes as f64 * 8.0) / (mbps * 1e6)
}

/// Effective device→fog uplink bandwidth when `devices` sources share one
/// fog access point (contention model of §II-C: more fog nodes = more
/// access points = wider aggregate bandwidth).
pub fn fog_uplink_mbps(p: &NetProfile, devices: usize) -> f64 {
    let aggregate = p.device_uplink_mbps * devices.max(1) as f64;
    aggregate.min(p.ap_capacity_mbps)
}

/// Effective device→cloud bandwidth: all devices funnel through the WAN
/// backhaul; long-haul capacity caps the aggregate.
pub fn cloud_uplink_mbps(p: &NetProfile, devices: usize) -> f64 {
    let aggregate = p.device_uplink_mbps * devices.max(1) as f64;
    aggregate.min(p.wan_capacity_mbps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use profiles::NetKind;

    #[test]
    fn transfer_time_scales_linearly() {
        let t1 = transfer_time_s(1_000_000, 10.0, 0.0);
        let t2 = transfer_time_s(2_000_000, 10.0, 0.0);
        assert!((t2 - 2.0 * t1).abs() < 1e-12);
        assert!((t1 - 0.8).abs() < 1e-9); // 8 Mbit over 10 Mbps
    }

    #[test]
    fn contention_caps_at_ap_capacity() {
        let p = NetProfile::get(NetKind::Wifi);
        let few = fog_uplink_mbps(&p, 1);
        let many = fog_uplink_mbps(&p, 1000);
        assert!(few <= many);
        assert_eq!(many, p.ap_capacity_mbps);
    }

    /// Calibration check: SIoT-sized upload (per §II-C: 16216 × 52 × 8 B
    /// over 8 devices) must show the paper's collection-latency reduction
    /// band when moving cloud → single fog.
    #[test]
    fn cloud_to_fog_reduction_matches_paper_band() {
        let bytes = 16216usize * 52 * 8;
        let devices = 8;
        for (kind, expect) in [
            (NetKind::Cell4G, 0.64),
            (NetKind::Cell5G, 0.67),
            (NetKind::Wifi, 0.61),
        ] {
            let p = NetProfile::get(kind);
            let cloud = transfer_time_s(
                bytes,
                cloud_uplink_mbps(&p, devices),
                p.wan_rtt_s,
            );
            // single-fog serving runs on the type-C node (share 1.3)
            let fog = transfer_time_s(
                bytes,
                fog_uplink_mbps(&p, devices) * 1.3,
                p.lan_rtt_s,
            );
            let reduction = 1.0 - fog / cloud;
            assert!(
                (reduction - expect).abs() < 0.08,
                "{kind:?}: reduction {reduction:.3}, paper {expect}"
            );
        }
    }
}
