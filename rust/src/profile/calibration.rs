//! Proxy-guided offline profiling (paper §III-B setup phase): uniformly
//! sample subgraphs of varying cardinality from the initial graph —
//! 20 samples per cardinality axis to preserve the degree distribution —
//! measure execution latency for each, and fit the node's PerfModel.

use crate::graph::{subgraph, Graph, LocalGraph};
use crate::util::rng::Rng;

use super::model::{Cardinality, PerfModel, Sample};

/// Default vertex-count axes, as fractions of |V|.
pub const DEFAULT_FRACTIONS: [f64; 5] = [0.05, 0.1, 0.2, 0.35, 0.6];
pub const SAMPLES_PER_AXIS: usize = 20;

/// Build the calibration set: BFS-grown subgraphs (preserving locality the
/// way real partitions do) at each size axis.
pub fn calibration_set(g: &Graph, fractions: &[f64], samples_per: usize,
                       seed: u64) -> Vec<LocalGraph> {
    let nv = g.num_vertices();
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for &f in fractions {
        let target = ((nv as f64 * f) as usize).clamp(2, nv);
        for _ in 0..samples_per {
            let verts = bfs_sample(g, target, &mut rng);
            out.push(subgraph::extract_one(g, &verts));
        }
    }
    out
}

/// BFS region sample of ~`target` vertices from a random seed (falls back
/// to extra random seeds when components are exhausted).
fn bfs_sample(g: &Graph, target: usize, rng: &mut Rng) -> Vec<u32> {
    let nv = g.num_vertices();
    let mut taken = vec![false; nv];
    let mut out: Vec<u32> = Vec::with_capacity(target);
    let mut queue = std::collections::VecDeque::new();
    while out.len() < target {
        if queue.is_empty() {
            // new seed
            let mut s = rng.usize_below(nv);
            let mut guard = 0;
            while taken[s] {
                s = rng.usize_below(nv);
                guard += 1;
                if guard > 10 * nv {
                    return out;
                }
            }
            taken[s] = true;
            out.push(s as u32);
            queue.push_back(s);
            continue;
        }
        let x = queue.pop_front().unwrap();
        for &u in g.neighbors(x) {
            if out.len() >= target {
                break;
            }
            if !taken[u as usize] {
                taken[u as usize] = true;
                out.push(u);
                queue.push_back(u as usize);
            }
        }
    }
    out
}

/// Run the measurement closure over the calibration set and fit the model.
/// `measure` returns the observed execution latency in seconds for one
/// subgraph (on the node being profiled).
pub fn profile_node<F>(set: &[LocalGraph], mut measure: F) -> PerfModel
where
    F: FnMut(&LocalGraph) -> f64,
{
    let samples: Vec<Sample> = set
        .iter()
        .map(|sg| {
            let (v, n) = sg.cardinality();
            Sample {
                card: Cardinality::new(v, n),
                latency_s: measure(sg),
            }
        })
        .collect();
    PerfModel::fit(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn calibration_set_spans_axes() {
        let (g, _) = generate::sbm(2000, 8000, 8, 0.9, 2);
        let set = calibration_set(&g, &[0.05, 0.2], 5, 3);
        assert_eq!(set.len(), 10);
        let small = set[0].n_local;
        let large = set[5].n_local;
        assert!(small >= 90 && small <= 110, "small {small}");
        assert!(large >= 380 && large <= 420, "large {large}");
        // locality: BFS samples should carry fewer halo than random sets
        for sg in &set {
            assert!(sg.n_halo() < sg.n_local * 6);
        }
    }

    #[test]
    fn bfs_sample_is_connectedish() {
        let (g, _) = generate::sbm(500, 2500, 4, 0.9, 7);
        let mut rng = Rng::new(1);
        let verts = bfs_sample(&g, 50, &mut rng);
        assert_eq!(verts.len(), 50);
        let set: std::collections::HashSet<u32> =
            verts.iter().copied().collect();
        assert_eq!(set.len(), 50);
        // most sampled vertices have a sampled neighbor
        let with_nbr = verts
            .iter()
            .filter(|&&v| {
                g.neighbors(v as usize).iter().any(|u| set.contains(u))
            })
            .count();
        assert!(with_nbr >= 45);
    }

    #[test]
    fn profile_node_fits_synthetic_latency() {
        let (g, _) = generate::sbm(3000, 12_000, 8, 0.9, 4);
        let set = calibration_set(&g, &DEFAULT_FRACTIONS, 8, 5);
        // synthetic executor: latency = 2e-6 V + 4e-7 N + 1ms
        let model = profile_node(&set, |sg| {
            let (v, n) = sg.cardinality();
            2e-6 * v as f64 + 4e-7 * n as f64 + 1e-3
        });
        assert!((model.beta_v - 2e-6).abs() < 2e-7, "{model:?}");
        assert!((model.beta_n - 4e-7).abs() < 2e-7, "{model:?}");
        assert!(model.r2 > 0.99);
    }
}
