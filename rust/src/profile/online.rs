//! Online profiler (paper §III-B runtime phase): tracks each fog node's
//! measured execution time, derives the load factor
//! `η = T_real(c) / ω(⟨c⟩)`,
//! and predicts the latency of any other cardinality c' as η · ω(⟨c'⟩) —
//! the two-step lightweight estimation the paper uses instead of refitting.

use super::model::{Cardinality, PerfModel};

/// One recorder-sourced measurement: the per-request kernel seconds a
/// fog's wall `kernel` spans amounted to at cardinality `c`. The
/// measured executor derives these from the same seconds the obs
/// plane records (`obs::span::Phase::Kernel`), so the profiler is a
/// consumer of flight-recorder observations rather than a parallel
/// timing authority.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Observation {
    /// Partition cardinality ⟨|V|, |N_V|⟩ the measurement was taken at.
    pub c: Cardinality,
    /// Per-request kernel seconds (batch-amortized).
    pub real_s: f64,
}

impl Observation {
    pub fn new(c: Cardinality, real_s: f64) -> Observation {
        Observation { c, real_s }
    }
}

/// Rolling online state for one fog node.
#[derive(Clone, Debug)]
pub struct OnlineProfiler {
    pub offline: PerfModel,
    /// Smoothed load factor η (1.0 = unloaded baseline).
    pub eta: f64,
    /// EWMA smoothing for η updates.
    pub alpha: f64,
    /// Most recent raw measurement.
    pub last_real_s: f64,
    pub observations: u64,
}

impl OnlineProfiler {
    pub fn new(offline: PerfModel) -> Self {
        Self {
            offline,
            eta: 1.0,
            alpha: 0.5,
            last_real_s: 0.0,
            observations: 0,
        }
    }

    /// Consume one flight-recorder observation (the serving-loop
    /// entry point; `observe` is the underlying primitive).
    pub fn consume(&mut self, obs: Observation) {
        self.observe(obs.c, obs.real_s);
    }

    /// Record a measured execution of cardinality `c` taking `real_s`.
    pub fn observe(&mut self, c: Cardinality, real_s: f64) {
        let predicted = self.offline.predict(c).max(1e-9);
        let eta_now = real_s / predicted;
        self.eta = if self.observations == 0 {
            eta_now
        } else {
            self.alpha * eta_now + (1.0 - self.alpha) * self.eta
        };
        self.last_real_s = real_s;
        self.observations += 1;
    }

    /// Two-step estimate: η · ω(⟨c'⟩).
    pub fn predict(&self, c: Cardinality) -> f64 {
        self.eta * self.offline.predict(c)
    }

    /// Export an η-scaled PerfModel (what the metadata server aggregates
    /// and feeds back into IEP re-planning — the ω' of Alg. 2 line 1).
    pub fn scaled_model(&self) -> PerfModel {
        PerfModel {
            beta_v: self.offline.beta_v * self.eta,
            beta_n: self.offline.beta_n * self.eta,
            intercept: self.offline.intercept * self.eta,
            r2: self.offline.r2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_model() -> PerfModel {
        PerfModel { beta_v: 1e-6, beta_n: 1e-7, intercept: 0.0, r2: 1.0 }
    }

    #[test]
    fn eta_tracks_load_increase() {
        let mut p = OnlineProfiler::new(base_model());
        let c = Cardinality::new(1000, 5000);
        let baseline = p.offline.predict(c);
        // node suddenly 3x slower
        p.observe(c, baseline * 3.0);
        assert!((p.eta - 3.0).abs() < 1e-9);
        // prediction for a DIFFERENT cardinality scales by eta
        let c2 = Cardinality::new(4000, 20_000);
        assert!((p.predict(c2) - 3.0 * p.offline.predict(c2)).abs() < 1e-12);
    }

    #[test]
    fn eta_smooths_over_observations() {
        let mut p = OnlineProfiler::new(base_model());
        let c = Cardinality::new(1000, 5000);
        let base = p.offline.predict(c);
        p.observe(c, base * 4.0);
        p.observe(c, base * 1.0);
        assert!(p.eta > 1.0 && p.eta < 4.0);
        assert_eq!(p.observations, 2);
    }

    #[test]
    fn consume_matches_observe() {
        let mut a = OnlineProfiler::new(base_model());
        let mut b = OnlineProfiler::new(base_model());
        let c = Cardinality::new(1500, 6000);
        a.observe(c, 0.004);
        b.consume(Observation::new(c, 0.004));
        assert_eq!(a.eta, b.eta);
        assert_eq!(a.observations, b.observations);
    }

    #[test]
    fn scaled_model_equals_prediction() {
        let mut p = OnlineProfiler::new(base_model());
        let c = Cardinality::new(2000, 9000);
        p.observe(c, p.offline.predict(c) * 2.0);
        let m = p.scaled_model();
        let c2 = Cardinality::new(777, 3210);
        assert!((m.predict(c2) - p.predict(c2)).abs() < 1e-12);
    }
}
