//! GNN-oriented profiling methodology (paper §III-B): offline proxy-guided
//! calibration fitting per-node regression latency models, and the
//! lightweight online load-factor tracker that keeps them current.

pub mod calibration;
pub mod model;
pub mod online;

pub use model::{Cardinality, PerfModel, Sample};
pub use online::{Observation, OnlineProfiler};
