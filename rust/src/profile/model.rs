//! Regression-based latency estimation models ω(⟨c⟩) — paper Eq. (3):
//! `latency = β · ⟨|V|, |N_V|⟩ + ε`.
//!
//! One model per (fog-node, GNN-model) pair, fitted on the calibration
//! set and refreshed online by the load factor η (§III-B runtime phase).

use crate::util::stats;

/// Cardinality of a subgraph from the GNN's perspective: owned vertices
/// and their one-hop neighbor multiset size (== local edge count).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cardinality {
    pub vertices: usize,
    pub neighbors: usize,
}

impl Cardinality {
    pub fn new(vertices: usize, neighbors: usize) -> Self {
        Self { vertices, neighbors }
    }
}

/// One calibration observation.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub card: Cardinality,
    pub latency_s: f64,
}

/// Fitted linear latency model.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub beta_v: f64,
    pub beta_n: f64,
    pub intercept: f64,
    /// R² of the fit on its training samples (profiler quality metric,
    /// surfaced in Fig. 14).
    pub r2: f64,
}

impl PerfModel {
    pub fn fit(samples: &[Sample]) -> PerfModel {
        assert!(samples.len() >= 3, "need >=3 calibration samples");
        let xs: Vec<Vec<f64>> = samples
            .iter()
            .map(|s| vec![s.card.vertices as f64, s.card.neighbors as f64])
            .collect();
        let ys: Vec<f64> = samples.iter().map(|s| s.latency_s).collect();
        let (beta, intercept) = stats::linreg(&xs, &ys);
        let model = PerfModel {
            beta_v: beta[0],
            beta_n: beta[1],
            intercept,
            r2: 0.0,
        };
        let mean_y = stats::mean(&ys);
        let ss_tot: f64 = ys.iter().map(|y| (y - mean_y).powi(2)).sum();
        let ss_res: f64 = samples
            .iter()
            .map(|s| (s.latency_s - model.predict(s.card)).powi(2))
            .sum();
        let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
        PerfModel { r2, ..model }
    }

    /// ω(⟨c⟩): predicted execution latency in seconds.
    pub fn predict(&self, c: Cardinality) -> f64 {
        (self.beta_v * c.vertices as f64
            + self.beta_n * c.neighbors as f64
            + self.intercept)
            .max(0.0)
    }

    /// A conservative default before any calibration has run: linear in
    /// both cardinality axes with magnitudes typical of CPU GNN layers.
    pub fn uncalibrated() -> PerfModel {
        PerfModel {
            beta_v: 3e-6,
            beta_n: 4e-7,
            intercept: 2e-3,
            r2: 0.0,
        }
    }

    /// Per-MODEL uncalibrated defaults for mixed-blend serving: one ω
    /// per GNN architecture, scaled by its relative per-layer cost
    /// (combine width, attention overhead, temporal window), so the
    /// multi-tenant planner prices a gat tenant's partition heavier
    /// than a gcn tenant's on the same fog before any calibration.
    /// `gcn` (and unknown names) fall back to `uncalibrated()`, so
    /// legacy single-model paths are unchanged.
    pub fn uncalibrated_for(model: &str) -> PerfModel {
        let base = PerfModel::uncalibrated();
        // relative (vertex, neighbor, fixed) cost factors vs gcn
        let (kv, kn, kc) = match model {
            "sage" => (1.25, 1.1, 1.0),   // concat combine, 2F GEMM
            "gat" => (1.6, 1.5, 1.2),     // per-edge attention scores
            "astgcn" => (2.2, 1.8, 1.5),  // T-window temporal block
            _ => (1.0, 1.0, 1.0),
        };
        PerfModel {
            beta_v: base.beta_v * kv,
            beta_n: base.beta_n * kn,
            intercept: base.intercept * kc,
            r2: base.r2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_samples(bv: f64, bn: f64, c: f64, noise: f64) -> Vec<Sample> {
        let mut rng = crate::util::rng::Rng::new(5);
        let mut out = Vec::new();
        for &v in &[100usize, 500, 1000, 4000, 8000] {
            for _ in 0..20 {
                let n = v * (2 + rng.usize_below(15));
                let lat = bv * v as f64 + bn * n as f64 + c
                    + rng.normal() * noise;
                out.push(Sample {
                    card: Cardinality::new(v, n),
                    latency_s: lat.max(0.0),
                });
            }
        }
        out
    }

    #[test]
    fn fit_recovers_coefficients() {
        let samples = synth_samples(2e-6, 5e-7, 1e-3, 0.0);
        let m = PerfModel::fit(&samples);
        assert!((m.beta_v - 2e-6).abs() < 1e-8);
        assert!((m.beta_n - 5e-7).abs() < 1e-9);
        assert!(m.r2 > 0.999);
    }

    #[test]
    fn noisy_fit_predicts_within_10pct() {
        // the ±10% band of Fig. 14 (noise ~4% of the smallest latency)
        let samples = synth_samples(2e-6, 5e-7, 2e-3, 1e-4);
        let m = PerfModel::fit(&samples);
        let mut within = 0;
        for s in &samples {
            let p = m.predict(s.card);
            if (p - s.latency_s).abs() / s.latency_s.max(1e-9) < 0.10 {
                within += 1;
            }
        }
        assert!(
            within as f64 > samples.len() as f64 * 0.9,
            "{within}/{} within ±10%",
            samples.len()
        );
    }

    #[test]
    fn per_model_defaults_order_by_architecture_cost() {
        let c = Cardinality::new(1000, 6000);
        let gcn = PerfModel::uncalibrated_for("gcn").predict(c);
        let sage = PerfModel::uncalibrated_for("sage").predict(c);
        let gat = PerfModel::uncalibrated_for("gat").predict(c);
        let ast = PerfModel::uncalibrated_for("astgcn").predict(c);
        assert!(gcn < sage && sage < gat && gat < ast,
                "{gcn} {sage} {gat} {ast}");
        // gcn and unknown models are the legacy default, unchanged
        assert_eq!(gcn, PerfModel::uncalibrated().predict(c));
        assert_eq!(PerfModel::uncalibrated_for("mlp").predict(c), gcn);
    }

    #[test]
    fn predict_is_monotone_in_cardinality() {
        let m = PerfModel::fit(&synth_samples(2e-6, 5e-7, 1e-3, 0.0));
        let small = m.predict(Cardinality::new(100, 500));
        let large = m.predict(Cardinality::new(10_000, 80_000));
        assert!(large > small);
    }

    #[test]
    fn never_predicts_negative() {
        let m = PerfModel {
            beta_v: -1e-3,
            beta_n: 0.0,
            intercept: 0.0,
            r2: 0.0,
        };
        assert_eq!(m.predict(Cardinality::new(1000, 0)), 0.0);
    }
}
