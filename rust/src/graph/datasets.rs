//! Dataset twins — synthetic stand-ins for SIoT / Yelp / PeMS plus the
//! paper's own RMAT series (Table III), with matched |V|, |E|, feature
//! dims, label cardinality and the feature *character* each mechanism
//! depends on (one-hot sparsity for SIoT, dense embeddings for Yelp,
//! daily-periodic traffic series for PeMS). See DESIGN.md's substitution
//! log for the fidelity argument.
//!
//! These constants are mirrored in python/compile/specs.py; the graphs
//! themselves are generated HERE only (single source of truth) and the
//! Python training path reads the emitted .fgr files.

use std::path::Path;

use crate::util::rng::{mix64, Rng};

use super::csr::Graph;
use super::generate;

/// Static description of a dataset twin (mirrors specs.DatasetSpec).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub vertices: usize,
    pub edges: usize, // undirected
    pub feature_dim: usize,
    pub classes: usize,
    pub duration: usize, // stored timesteps
    pub window: usize,   // per-inference window
    pub seed: u64,
}

impl DatasetSpec {
    pub fn directed_edges(&self) -> usize {
        self.edges * 2
    }

    /// Flattened per-vertex input dim of one inference (F · window).
    pub fn input_dim(&self) -> usize {
        self.feature_dim * self.window
    }

    /// Raw upload payload per vertex per inference at full precision, in
    /// bits (the Q of Theorem 2: our features originate as f64 sensor
    /// readings, matching the paper's 64-bit default).
    pub fn bits_per_vertex(&self) -> usize {
        self.input_dim() * 64
    }
}

pub const SIOT: DatasetSpec = DatasetSpec {
    name: "siot",
    vertices: 16216,
    edges: 146117,
    feature_dim: 52,
    classes: 2,
    duration: 1,
    window: 1,
    seed: 11,
};

pub const YELP: DatasetSpec = DatasetSpec {
    name: "yelp",
    vertices: 10000,
    edges: 15683,
    feature_dim: 100,
    classes: 2,
    duration: 1,
    window: 1,
    seed: 13,
};

pub const PEMS: DatasetSpec = DatasetSpec {
    name: "pems",
    vertices: 307,
    edges: 340,
    feature_dim: 3,
    classes: 0,
    duration: 2016, // 7 days of 5-minute readings
    window: 12,
    seed: 17,
};

pub const RMAT_SERIES: [DatasetSpec; 5] = [
    DatasetSpec { name: "rmat20k", vertices: 20_000, edges: 199_000,
                  feature_dim: 32, classes: 8, duration: 1, window: 1,
                  seed: 21 },
    DatasetSpec { name: "rmat40k", vertices: 40_000, edges: 799_000,
                  feature_dim: 32, classes: 8, duration: 1, window: 1,
                  seed: 22 },
    DatasetSpec { name: "rmat60k", vertices: 60_000, edges: 1_790_000,
                  feature_dim: 32, classes: 8, duration: 1, window: 1,
                  seed: 23 },
    DatasetSpec { name: "rmat80k", vertices: 80_000, edges: 3_190_000,
                  feature_dim: 32, classes: 8, duration: 1, window: 1,
                  seed: 24 },
    DatasetSpec { name: "rmat100k", vertices: 100_000, edges: 4_990_000,
                  feature_dim: 32, classes: 8, duration: 1, window: 1,
                  seed: 25 },
];

pub fn all_specs() -> Vec<DatasetSpec> {
    let mut v = vec![SIOT, YELP, PEMS];
    v.extend_from_slice(&RMAT_SERIES);
    v
}

pub fn spec_by_name(name: &str) -> Option<DatasetSpec> {
    all_specs().into_iter().find(|s| s.name == name)
}

/// Error for a dataset name outside Table III. Surfaces to the CLI as an
/// exit-code-2 error instead of a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UnknownDataset(pub String);

impl std::fmt::Display for UnknownDataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unknown dataset {}", self.0)
    }
}

impl std::error::Error for UnknownDataset {}

/// Generate a dataset twin by name.
pub fn generate(name: &str) -> Result<Graph, UnknownDataset> {
    match name {
        "siot" => Ok(gen_siot()),
        "yelp" => Ok(gen_yelp()),
        "pems" => Ok(gen_pems()),
        n if n.starts_with("rmat") => match spec_by_name(n) {
            Some(spec) => Ok(gen_rmat_twin(spec)),
            None => Err(UnknownDataset(n.to_string())),
        },
        other => Err(UnknownDataset(other.to_string())),
    }
}

/// Load from `dir/<name>.fgr` if present, else generate (and cache).
pub fn load_or_generate(dir: &Path, name: &str)
                        -> Result<Graph, UnknownDataset> {
    let p = dir.join(format!("{name}.fgr"));
    if p.exists() {
        if let Ok(g) = super::io::read_fgr(&p) {
            return Ok(g);
        }
    }
    let g = generate(name)?;
    if dir.exists() {
        let _ = super::io::write_fgr(&p, &g);
    }
    Ok(g)
}

// ---------------------------------------------------------------- SIoT ----

const SIOT_TYPES: usize = 14;
const SIOT_BRANDS: usize = 30;
const SIOT_MISC: usize = 8;

/// SIoT: socially-connected IoT devices in Santander. One-hot device
/// type + brand + misc binary attributes (52 dims, sparse — the property
/// DAQ + LZ4 exploits), public/private label correlated with device type.
fn gen_siot() -> Graph {
    let spec = SIOT;
    let (mut g, comm) =
        generate::sbm(spec.vertices, spec.edges, 24, 0.82, spec.seed);
    let mut rng = Rng::new(spec.seed ^ 0xF0F0);
    let v = spec.vertices;
    let mut features = vec![0f32; v * spec.feature_dim];
    let mut labels = vec![0i32; v];
    // public device types: 0..6 public-ish, 7..13 private-ish
    for i in 0..v {
        // device type correlates with community (streets host similar
        // devices), brand is noisier
        let ty = ((comm[i] as usize * 3) + rng.usize_below(5)) % SIOT_TYPES;
        let brand = (mix64(i as u64 * 31 + ty as u64) % SIOT_BRANDS as u64)
            as usize;
        let row = &mut features[i * 52..(i + 1) * 52];
        row[ty] = 1.0;
        row[SIOT_TYPES + brand] = 1.0;
        for m in 0..SIOT_MISC {
            if rng.bool(0.25) {
                row[SIOT_TYPES + SIOT_BRANDS + m] = 1.0;
            }
        }
        let public = ty < 7;
        labels[i] = (public ^ rng.bool(0.06)) as i32;
    }
    g.features = features;
    g.feature_dim = 52;
    g.num_classes = 2;
    g.labels = Some(labels);
    g
}

// ---------------------------------------------------------------- Yelp ----

/// Yelp-Chicago twin: review vertices with Word2Vec-like dense embeddings,
/// sparse co-history edges, spam/benign labels consistent within connected
/// components (same spammer account ⇒ shared history).
fn gen_yelp() -> Graph {
    let spec = YELP;
    let (mut g, _comm) =
        generate::sbm(spec.vertices, spec.edges, 400, 0.92, spec.seed);
    let mut rng = Rng::new(spec.seed ^ 0xABCD);
    let v = spec.vertices;
    // connected-component labels with per-vertex noise
    let comps = connected_components(&g);
    let mut comp_label = vec![0i32; comps.num_components];
    for l in comp_label.iter_mut() {
        *l = rng.bool(0.35) as i32; // ~35% spam components
    }
    let mut labels = vec![0i32; v];
    let mut features = vec![0f32; v * spec.feature_dim];
    // class centroids in 100-dim space
    let mut centroids = [[0f32; 100]; 2];
    for c in centroids.iter_mut() {
        for x in c.iter_mut() {
            *x = rng.normal_f32(0.0, 1.0);
        }
    }
    for i in 0..v {
        let mut l = comp_label[comps.component[i] as usize];
        if rng.bool(0.06) {
            l ^= 1;
        }
        labels[i] = l;
        // Word2Vec-ish embeddings with substantial class overlap (the
        // paper's Yelp accuracies sit at 86-92%, not a separable toy)
        let row = &mut features[i * 100..(i + 1) * 100];
        for (d, x) in row.iter_mut().enumerate() {
            *x = 0.28 * centroids[l as usize][d]
                + rng.normal_f32(0.0, 1.0);
        }
    }
    g.features = features;
    g.feature_dim = 100;
    g.num_classes = 2;
    g.labels = Some(labels);
    g
}

pub struct Components {
    pub component: Vec<u32>,
    pub num_components: usize,
}

/// BFS connected components (also used by partition tests).
pub fn connected_components(g: &Graph) -> Components {
    let v = g.num_vertices();
    let mut component = vec![u32::MAX; v];
    let mut n = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for s in 0..v {
        if component[s] != u32::MAX {
            continue;
        }
        component[s] = n;
        queue.push_back(s);
        while let Some(x) = queue.pop_front() {
            for &y in g.neighbors(x) {
                if component[y as usize] == u32::MAX {
                    component[y as usize] = n;
                    queue.push_back(y as usize);
                }
            }
        }
        n += 1;
    }
    Components { component, num_components: n as usize }
}

// ---------------------------------------------------------------- PeMS ----

/// PeMS-twin: freeway corridor sensors, 7 days of 5-minute (flow, speed,
/// occupancy) readings with daily periodicity, rush hours, congestion
/// events and sensor noise.
fn gen_pems() -> Graph {
    let spec = PEMS;
    let (mut g, coords) =
        generate::road_network(spec.vertices, spec.edges, 2, spec.seed);
    let mut rng = Rng::new(spec.seed ^ 0x7777);
    let v = spec.vertices;
    let t_total = spec.duration;
    let mut features = vec![0f32; v * 3 * t_total];
    for i in 0..v {
        let base = rng.range_f64(150.0, 450.0) as f32; // veh / 5 min
        let capacity = base * 2.2;
        let rush_am = rng.range_f64(0.30, 0.36); // fraction of day
        let rush_pm = rng.range_f64(0.70, 0.76);
        let mut congestion_until = 0usize;
        for t in 0..t_total {
            let day_frac = (t % 288) as f64 / 288.0;
            let weekend = (t / 288) % 7 >= 5;
            let mut flow = base as f64
                * (0.55
                    + 0.45
                        * ((day_frac - 0.5) * std::f64::consts::TAU).cos()
                            .max(-0.8)
                    + 0.9 * gaussian_bump(day_frac, rush_am, 0.03)
                    + 1.0 * gaussian_bump(day_frac, rush_pm, 0.035));
            if weekend {
                flow *= 0.7;
            }
            // rare congestion events: flow drops, occupancy spikes
            if congestion_until == 0 && rng.bool(0.0015) {
                congestion_until = t + 6 + rng.usize_below(12);
            }
            let congested = t < congestion_until;
            if congested {
                flow *= 0.45;
            }
            if t >= congestion_until {
                congestion_until = 0;
            }
            flow = (flow + rng.normal() * 12.0).max(5.0);
            let vc = (flow / capacity as f64).min(1.1);
            let mut speed = 70.0 * (1.0 - 0.65 * vc * vc);
            if congested {
                speed *= 0.5;
            }
            speed = (speed + rng.normal() * 2.0).clamp(4.0, 80.0);
            let occupancy =
                (vc * 0.35 + if congested { 0.3 } else { 0.0 }
                    + rng.normal() * 0.01)
                    .clamp(0.0, 1.0);
            let idx = i * 3 * t_total;
            features[idx + t] = flow as f32;
            features[idx + t_total + t] = speed as f32;
            features[idx + 2 * t_total + t] = occupancy as f32;
        }
    }
    g.features = features;
    g.feature_dim = 3;
    g.duration = t_total;
    g.num_classes = 0;
    g.coords = Some(coords);
    g
}

fn gaussian_bump(x: f64, center: f64, width: f64) -> f64 {
    let d = (x - center) / width;
    (-0.5 * d * d).exp()
}

// ---------------------------------------------------------------- RMAT ----

/// RMAT twins: paper's Appendix D — RMAT topology at SIoT-like density,
/// Node2Vec-like 32-dim features, 8 community-flavored classes.
fn gen_rmat_twin(spec: DatasetSpec) -> Graph {
    let mut g = generate::rmat(
        spec.vertices,
        spec.edges,
        spec.seed,
        (0.57, 0.19, 0.19, 0.05),
    );
    let mut rng = Rng::new(spec.seed ^ 0x5150);
    let v = spec.vertices;
    let classes = spec.classes;
    let mut centroids = vec![0f32; classes * 32];
    for x in centroids.iter_mut() {
        *x = rng.normal_f32(0.0, 1.0);
    }
    let mut labels = vec![0i32; v];
    let mut features = vec![0f32; v * 32];
    for i in 0..v {
        let c = (mix64(spec.seed ^ (i as u64 * 0x9E37)) % classes as u64)
            as usize;
        labels[i] = c as i32;
        let row = &mut features[i * 32..(i + 1) * 32];
        for (d, x) in row.iter_mut().enumerate() {
            *x = centroids[c * 32 + d] + rng.normal_f32(0.0, 0.7);
        }
    }
    g.features = features;
    g.feature_dim = 32;
    g.num_classes = classes;
    g.labels = Some(labels);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn siot_matches_table_iii() {
        let g = gen_siot();
        assert_eq!(g.num_vertices(), 16216);
        assert_eq!(g.undirected_edges(), 146117);
        assert_eq!(g.feature_dim, 52);
        assert_eq!(g.num_classes, 2);
        g.validate().unwrap();
        // one-hot-ish sparsity: most entries zero
        let nz = g.features.iter().filter(|&&x| x != 0.0).count();
        let frac = nz as f64 / g.features.len() as f64;
        assert!(frac < 0.12, "siot features too dense: {frac}");
        // labels are informative: majority of same-type devices share label
        let labels = g.labels.as_ref().unwrap();
        let ones = labels.iter().filter(|&&l| l == 1).count();
        assert!(ones > 1000 && ones < 15000);
    }

    #[test]
    fn yelp_matches_table_iii() {
        let g = gen_yelp();
        assert_eq!(g.num_vertices(), 10000);
        assert_eq!(g.undirected_edges(), 15683);
        assert_eq!(g.feature_dim, 100);
        g.validate().unwrap();
    }

    #[test]
    fn pems_series_is_periodic_and_positive() {
        let g = gen_pems();
        assert_eq!(g.num_vertices(), 307);
        assert_eq!(g.undirected_edges(), 340);
        assert_eq!(g.duration, 2016);
        assert!(g.coords.is_some());
        // flow channel positive
        let t = g.duration;
        for i in (0..g.num_vertices()).step_by(37) {
            let flow = &g.features[i * 3 * t..i * 3 * t + t];
            assert!(flow.iter().all(|&x| x > 0.0));
            // daily autocorrelation: same time tomorrow closer than +6h
            let mut same = 0.0;
            let mut off = 0.0;
            for d in 0..5 {
                for k in (0..288).step_by(16) {
                    let a = flow[d * 288 + k];
                    same += (a - flow[(d + 1) * 288 + k]).abs();
                    off += (a - flow[d * 288 + (k + 144) % 288]).abs();
                }
            }
            assert!(same < off, "no daily periodicity at sensor {i}");
        }
    }

    #[test]
    fn rmat_twin_small_is_consistent() {
        // use the smallest spec but shrunk for test speed
        let spec = DatasetSpec { vertices: 2000, edges: 9000, ..RMAT_SERIES[0] };
        let g = gen_rmat_twin(spec);
        assert_eq!(g.num_vertices(), 2000);
        assert_eq!(g.undirected_edges(), 9000);
        assert_eq!(g.feature_dim, 32);
        let labels = g.labels.as_ref().unwrap();
        assert!(labels.iter().all(|&l| (0..8).contains(&l)));
    }

    #[test]
    fn specs_are_unique_and_resolvable() {
        let specs = all_specs();
        let names: std::collections::HashSet<_> =
            specs.iter().map(|s| s.name).collect();
        assert_eq!(names.len(), specs.len());
        for s in &specs {
            assert_eq!(spec_by_name(s.name).unwrap(), *s);
        }
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    fn unknown_dataset_is_an_error_not_a_panic() {
        assert!(matches!(generate("nope"), Err(UnknownDataset(_))));
        assert!(matches!(generate("rmat999k"), Err(UnknownDataset(_))));
        assert!(generate("pems").is_ok());
    }

    #[test]
    fn components_of_disconnected_graph() {
        let g = Graph::from_undirected_edges(6, &[(0, 1), (2, 3), (3, 4)]);
        let c = connected_components(&g);
        assert_eq!(c.num_components, 3);
        assert_eq!(c.component[0], c.component[1]);
        assert_eq!(c.component[2], c.component[4]);
        assert_ne!(c.component[0], c.component[5]);
    }
}
