//! Topology generators — the substrate behind the paper's dataset twins.
//!
//! * `rmat` — R-MAT (Chakrabarti et al., SDM'04), the generator the paper
//!   itself uses for its scalability graphs (Appendix D), with rejection of
//!   duplicates/self-loops until the exact target edge count is met.
//! * `sbm` — stochastic block model for the community-structured IoT/social
//!   twins (SIoT, Yelp).
//! * `road_network` — a freeway-corridor graph for the PeMS twin: a few
//!   parallel chains with interchange links, matching PeMS' 307/340
//!   vertex/edge shape and yielding plausible coordinates for Fig. 13(a).

use std::collections::HashSet;

use crate::util::rng::Rng;

use super::csr::Graph;

/// Exact-count R-MAT: samples edges by recursive quadrant descent with
/// probabilities (a, b, c, d), rejecting self loops and duplicates until
/// `num_edges` distinct undirected edges exist.
pub fn rmat(
    num_vertices: usize,
    num_edges: usize,
    seed: u64,
    probs: (f64, f64, f64, f64),
) -> Graph {
    let scale = (num_vertices as f64).log2().ceil() as u32;
    let n = num_vertices as u64;
    let (a, b, c, _d) = probs;
    let mut rng = Rng::new(seed);
    let mut seen: HashSet<(u32, u32)> = HashSet::with_capacity(num_edges * 2);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(num_edges);
    let max_undirected = num_vertices * (num_vertices - 1) / 2;
    assert!(
        num_edges <= max_undirected,
        "edge target exceeds complete graph"
    );
    while edges.len() < num_edges {
        let (mut x, mut y) = (0u64, 0u64);
        for level in 0..scale {
            let bit = 1u64 << (scale - 1 - level);
            // noise the quadrant probabilities slightly per level for
            // realism (standard smoothing trick)
            let r = rng.f64();
            if r < a {
                // top-left: nothing to add
            } else if r < a + b {
                y |= bit;
            } else if r < a + b + c {
                x |= bit;
            } else {
                x |= bit;
                y |= bit;
            }
        }
        if x >= n || y >= n || x == y {
            continue;
        }
        let key = (x.min(y) as u32, x.max(y) as u32);
        if seen.insert(key) {
            edges.push(key);
        }
    }
    Graph::from_undirected_edges(num_vertices, &edges)
}

/// Stochastic block model with exact edge count: `p_in` is the probability
/// mass of intra-community edges. Vertices are assigned to
/// `num_communities` round-robin-contiguous blocks; the returned community
/// assignment is useful for label synthesis.
pub fn sbm(
    num_vertices: usize,
    num_edges: usize,
    num_communities: usize,
    p_in: f64,
    seed: u64,
) -> (Graph, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let comm_of = |v: usize| (v * num_communities / num_vertices) as u32;
    // members per community (contiguous blocks)
    let mut bounds = Vec::with_capacity(num_communities + 1);
    for c in 0..=num_communities {
        bounds.push(c * num_vertices / num_communities);
    }
    let mut seen: HashSet<(u32, u32)> = HashSet::with_capacity(num_edges * 2);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(num_edges);
    let mut attempts: u64 = 0;
    while edges.len() < num_edges {
        attempts += 1;
        if attempts > (num_edges as u64) * 400 {
            panic!("sbm: cannot reach edge target (too dense?)");
        }
        let (u, v) = if rng.f64() < p_in {
            let c = rng.usize_below(num_communities);
            let lo = bounds[c];
            let hi = bounds[c + 1];
            if hi - lo < 2 {
                continue;
            }
            (
                (lo + rng.usize_below(hi - lo)) as u32,
                (lo + rng.usize_below(hi - lo)) as u32,
            )
        } else {
            (
                rng.usize_below(num_vertices) as u32,
                rng.usize_below(num_vertices) as u32,
            )
        };
        if u == v {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if seen.insert(key) {
            edges.push(key);
        }
    }
    let comm: Vec<u32> = (0..num_vertices).map(comm_of).collect();
    (Graph::from_undirected_edges(num_vertices, &edges), comm)
}

/// Freeway-corridor road network: `lanes` parallel chains of sensors with
/// periodic interchange links, plus extra ramp edges to hit the exact
/// target. Returns the graph and sensor coordinates.
pub fn road_network(
    num_vertices: usize,
    num_edges: usize,
    lanes: usize,
    seed: u64,
) -> (Graph, Vec<[f32; 2]>) {
    assert!(lanes >= 1);
    let mut rng = Rng::new(seed);
    let per_lane = num_vertices / lanes;
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut seen: HashSet<(u32, u32)> = HashSet::new();
    let push = |edges: &mut Vec<(u32, u32)>,
                    seen: &mut HashSet<(u32, u32)>,
                    a: u32,
                    b: u32| {
        if a != b {
            let key = (a.min(b), a.max(b));
            if seen.insert(key) {
                edges.push(key);
            }
        }
    };
    // chains
    for lane in 0..lanes {
        let start = lane * per_lane;
        let end = if lane == lanes - 1 {
            num_vertices
        } else {
            (lane + 1) * per_lane
        };
        for v in start..end - 1 {
            push(&mut edges, &mut seen, v as u32, (v + 1) as u32);
        }
    }
    // interchanges every ~20 sensors
    for lane in 0..lanes.saturating_sub(1) {
        let start = lane * per_lane;
        for k in (10..per_lane).step_by(20) {
            let a = (start + k) as u32;
            let b = (start + per_lane + k.min(per_lane - 1)) as u32;
            if (b as usize) < num_vertices && edges.len() < num_edges {
                push(&mut edges, &mut seen, a, b);
            }
        }
    }
    // random ramps until exact count
    let mut attempts = 0;
    while edges.len() < num_edges {
        attempts += 1;
        assert!(attempts < 1_000_000, "road_network: cannot reach target");
        let a = rng.usize_below(num_vertices) as u32;
        let off = 2 + rng.usize_below(8);
        let b = ((a as usize + off) % num_vertices) as u32;
        push(&mut edges, &mut seen, a, b);
    }
    edges.truncate(num_edges);
    // coordinates: gentle S-curve along each lane
    let mut coords = Vec::with_capacity(num_vertices);
    for v in 0..num_vertices {
        let lane = (v / per_lane).min(lanes - 1);
        let k = v - lane * per_lane;
        let t = k as f32 / per_lane.max(1) as f32;
        let x = t * 100.0;
        let y = lane as f32 * 8.0 + 6.0 * (t * 6.0).sin()
            + rng.normal_f32(0.0, 0.3);
        coords.push([x, y]);
    }
    (Graph::from_undirected_edges(num_vertices, &edges), coords)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_hits_exact_count_and_is_skewed() {
        let g = rmat(1 << 10, 4000, 3, (0.57, 0.19, 0.19, 0.05));
        assert_eq!(g.undirected_edges(), 4000);
        g.validate().unwrap();
        let mut degs = g.degrees();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // power-lawish: top vertex much hotter than median
        assert!(degs[0] as f64 > 4.0 * degs[degs.len() / 2] as f64);
    }

    #[test]
    fn rmat_is_deterministic() {
        let a = rmat(512, 1500, 9, (0.57, 0.19, 0.19, 0.05));
        let b = rmat(512, 1500, 9, (0.57, 0.19, 0.19, 0.05));
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.indptr, b.indptr);
    }

    #[test]
    fn sbm_exact_count_and_community_locality() {
        let (g, comm) = sbm(1000, 5000, 10, 0.9, 5);
        assert_eq!(g.undirected_edges(), 5000);
        g.validate().unwrap();
        // most edges intra-community
        let mut intra = 0usize;
        for v in 0..g.num_vertices() {
            for &u in g.neighbors(v) {
                if comm[v] == comm[u as usize] {
                    intra += 1;
                }
            }
        }
        assert!(
            intra as f64 / g.num_edges() as f64 > 0.75,
            "intra fraction {}",
            intra as f64 / g.num_edges() as f64
        );
    }

    #[test]
    fn road_network_shape() {
        let (g, coords) = road_network(307, 340, 2, 17);
        assert_eq!(g.num_vertices(), 307);
        assert_eq!(g.undirected_edges(), 340);
        assert_eq!(coords.len(), 307);
        g.validate().unwrap();
        // road networks are near-planar: max degree stays small
        assert!(*g.degrees().iter().max().unwrap() <= 8);
    }
}
