//! CSR graph container — the in-memory form of the `.fgr` interchange
//! format shared with the Python compile path (python/compile/fgio.py).
//!
//! `indices[indptr[v]..indptr[v+1]]` are v's out-neighbors; all dataset
//! twins are symmetric (each undirected edge stored in both directions),
//! matching the paper's undirected IoT graphs.

use std::collections::HashSet;

/// A vertex-featured graph. Features are `[V, F]` (static graphs) or
/// `[V, F, T]` row-major (temporal series, PeMS).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub indptr: Vec<u64>,
    pub indices: Vec<u32>,
    pub features: Vec<f32>,
    pub feature_dim: usize,
    pub duration: usize,
    pub num_classes: usize,
    pub labels: Option<Vec<i32>>,
    pub coords: Option<Vec<[f32; 2]>>,
}

impl Graph {
    pub fn num_vertices(&self) -> usize {
        self.indptr.len().saturating_sub(1)
    }

    /// Directed edge count (2x the undirected count for our symmetric twins).
    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }

    pub fn undirected_edges(&self) -> usize {
        self.num_edges() / 2
    }

    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.indices[self.indptr[v] as usize..self.indptr[v + 1] as usize]
    }

    pub fn degree(&self, v: usize) -> usize {
        (self.indptr[v + 1] - self.indptr[v]) as usize
    }

    pub fn degrees(&self) -> Vec<u32> {
        (0..self.num_vertices()).map(|v| self.degree(v) as u32).collect()
    }

    /// Feature row of vertex v (length F·T).
    pub fn feature_row(&self, v: usize) -> &[f32] {
        let w = self.feature_dim * self.duration.max(1);
        &self.features[v * w..(v + 1) * w]
    }

    /// Per-vertex feature payload in bytes at full (f32) precision —
    /// the φ of Eq. (5).
    pub fn bytes_per_vertex(&self) -> usize {
        self.feature_dim * self.duration.max(1) * 4
    }

    /// Build a symmetric CSR graph from undirected edge pairs.
    /// Duplicate pairs and self loops must already be removed.
    pub fn from_undirected_edges(
        num_vertices: usize,
        edges: &[(u32, u32)],
    ) -> Graph {
        let mut deg = vec![0u64; num_vertices];
        for &(a, b) in edges {
            debug_assert_ne!(a, b);
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut indptr = vec![0u64; num_vertices + 1];
        for v in 0..num_vertices {
            indptr[v + 1] = indptr[v] + deg[v];
        }
        let mut cursor: Vec<u64> = indptr[..num_vertices].to_vec();
        let mut indices = vec![0u32; indptr[num_vertices] as usize];
        for &(a, b) in edges {
            indices[cursor[a as usize] as usize] = b;
            cursor[a as usize] += 1;
            indices[cursor[b as usize] as usize] = a;
            cursor[b as usize] += 1;
        }
        // sort adjacency lists for deterministic layout + fast membership
        for v in 0..num_vertices {
            indices[indptr[v] as usize..indptr[v + 1] as usize]
                .sort_unstable();
        }
        Graph {
            indptr,
            indices,
            features: Vec::new(),
            feature_dim: 0,
            duration: 1,
            num_classes: 0,
            labels: None,
            coords: None,
        }
    }

    /// Undirected edge pairs (u < v), ascending — the canonical input
    /// `from_undirected_edges` round-trips through, and the seed the
    /// delta CSR's rebuild-from-scratch parity arm compares against.
    pub fn undirected_edge_pairs(&self) -> Vec<(u32, u32)> {
        let mut pairs = Vec::with_capacity(self.undirected_edges());
        for v in 0..self.num_vertices() {
            for &u in self.neighbors(v) {
                if u > v as u32 {
                    pairs.push((v as u32, u));
                }
            }
        }
        pairs
    }

    /// COO (src, dst) edge list, mirroring fgio.Graph.edge_list().
    pub fn edge_list(&self) -> (Vec<u32>, Vec<u32>) {
        let mut src = Vec::with_capacity(self.num_edges());
        for v in 0..self.num_vertices() {
            src.extend(
                std::iter::repeat(v as u32).take(self.degree(v)),
            );
        }
        (src, self.indices.clone())
    }

    /// Structural sanity: monotone indptr, in-range indices, symmetry.
    pub fn validate(&self) -> Result<(), String> {
        let v = self.num_vertices();
        if self.indptr.first() != Some(&0) {
            return Err("indptr[0] != 0".into());
        }
        for i in 0..v {
            if self.indptr[i] > self.indptr[i + 1] {
                return Err(format!("indptr not monotone at {i}"));
            }
        }
        if *self.indptr.last().unwrap() as usize != self.indices.len() {
            return Err("indptr[-1] != |indices|".into());
        }
        if self.indices.iter().any(|&u| u as usize >= v) {
            return Err("index out of range".into());
        }
        // spot-check symmetry on a deterministic sample
        let mut present: HashSet<(u32, u32)> = HashSet::new();
        for a in 0..v.min(2000) {
            for &b in self.neighbors(a) {
                present.insert((a as u32, b));
            }
        }
        for &(a, b) in present.iter() {
            if (b as usize) < v.min(2000) && !present.contains(&(b, a)) {
                return Err(format!("asymmetric edge ({a},{b})"));
            }
        }
        if self.feature_dim > 0 {
            let want = v * self.feature_dim * self.duration.max(1);
            if self.features.len() != want {
                return Err(format!(
                    "features len {} != {want}",
                    self.features.len()
                ));
            }
        }
        if let Some(l) = &self.labels {
            if l.len() != v {
                return Err("labels len mismatch".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_undirected_edges(4, &[(0, 1), (1, 2), (0, 2)])
    }

    #[test]
    fn builds_symmetric_csr() {
        let g = triangle();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 6);
        assert_eq!(g.undirected_edges(), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.neighbors(3), &[] as &[u32]);
        g.validate().unwrap();
    }

    #[test]
    fn edge_list_matches_degrees() {
        let g = triangle();
        let (src, dst) = g.edge_list();
        assert_eq!(src.len(), 6);
        assert_eq!(dst.len(), 6);
        assert_eq!(src[0], 0);
        assert_eq!(g.degree(0), 2);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut g = triangle();
        g.indices[0] = 99;
        assert!(g.validate().is_err());
    }
}
