//! Graph substrate: CSR container, binary IO (.fgr), topology generators,
//! the paper's dataset twins (Table III), and partition-local subgraph /
//! halo-exchange extraction for the distributed runtime.

pub mod csr;
pub mod datasets;
pub mod delta;
pub mod generate;
pub mod io;
pub mod subgraph;

pub use csr::Graph;
pub use datasets::DatasetSpec;
pub use delta::{
    ChurnPlan, ChurnSpec, ChurnSummary, DeltaCsr, TopologyEngine,
};
pub use subgraph::{ExchangePlan, LocalGraph};
