//! Partition-local subgraph extraction with halo vertices, plus the
//! cross-fog exchange plan the BSP runtime executes between GNN layers
//! (paper §III-E).
//!
//! For a data placement π, fog j owns local vertices L_j; to compute one
//! GNN layer for L_j it additionally needs the current activations of
//! every in-neighbor of L_j that lives elsewhere — the *halo* H_j. The
//! local index space is `[locals..., halo...]`, and the edge list contains
//! every edge whose destination is local (sources may be halo).
//!
//! Two grounding paths produce bit-identical results:
//!
//! * [`GroundingStream`] (the scale tier, and what [`extract`] uses) —
//!   grounds ONE fog's sub-CSR at a time against two flat O(V) scratch
//!   arrays, so peak memory is one sub-CSR plus scratch rather than all
//!   sub-CSRs plus per-fog remap `HashMap`s at once.
//! * [`extract_materialized`] — the original materialize-everything
//!   reference, kept for the parity gate and for the scale bench's
//!   peak-memory comparison.

use std::collections::HashMap;

use super::csr::Graph;

/// One fog's executable view of its partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LocalGraph {
    /// Global vertex ids; first `n_local` entries are owned, rest is halo.
    pub vertices: Vec<u32>,
    pub n_local: usize,
    /// COO edges in local index space; dst < n_local always.
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    /// Global in-degree of each local-space vertex (for GCN/SAGE
    /// normalization — must be the FULL-graph degree, not the local one).
    pub global_degree: Vec<u32>,
}

impl LocalGraph {
    pub fn n_total(&self) -> usize {
        self.vertices.len()
    }

    pub fn n_halo(&self) -> usize {
        self.vertices.len() - self.n_local
    }

    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    /// The cardinality ⟨|V|, |N_V|⟩ the profiler's latency model uses
    /// (paper §III-B): owned vertices and their one-hop neighbor count.
    pub fn cardinality(&self) -> (usize, usize) {
        (self.n_local, self.num_edges())
    }

    /// fnv1a64 over the sub-CSR's full contents — the per-partition
    /// topology fingerprint the incremental engine (graph/delta.rs)
    /// uses to prove preserved fogs were left bit-identical.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |x: u32| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(self.n_local as u32);
        for &v in &self.vertices {
            eat(v);
        }
        for &x in &self.src {
            eat(x);
        }
        for &x in &self.dst {
            eat(x);
        }
        for &x in &self.global_degree {
            eat(x);
        }
        h
    }

    /// Heap bytes held by this sub-CSR — the deterministic logical
    /// memory metric the scale bench compares across grounding paths
    /// (`VmHWM` is a process-wide high-water mark and cannot compare
    /// two phases within one run).
    pub fn heap_bytes(&self) -> usize {
        4 * (self.vertices.len()
            + self.src.len()
            + self.dst.len()
            + self.global_degree.len())
    }
}

/// Cross-fog halo exchange plan for one layer boundary: for each
/// (owner, requester) pair, which owner-local vertices to ship.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ExchangePlan {
    /// transfers[owner][requester] = owner-local indices (usize into the
    /// owner's `vertices[..n_local]`) that the requester needs.
    pub transfers: Vec<Vec<Vec<u32>>>,
}

impl ExchangePlan {
    /// Total vertices shipped in one synchronization round.
    pub fn total_vertices(&self) -> usize {
        self.transfers
            .iter()
            .flat_map(|row| row.iter().map(|v| v.len()))
            .sum()
    }

    /// Heap bytes held by the plan rows (see `LocalGraph::heap_bytes`).
    pub fn heap_bytes(&self) -> usize {
        self.transfers
            .iter()
            .flat_map(|row| row.iter().map(|v| v.len() * 4))
            .sum()
    }
}

/// Streamed grounding: yields one fog's [`LocalGraph`] at a time, then
/// the completed [`ExchangePlan`]. Instead of per-fog remap `HashMap`s,
/// two flat arrays index the whole graph:
///
/// * `owner_rank[v]` — v's position within its owner's local list
///   (what the materialized path recomputes as `owner_index` maps);
/// * `local_of[v]` — v's index in the CURRENT fog's local space
///   (`u32::MAX` = absent), reset between fogs by touching only the
///   vertices the finished fog saw.
///
/// Halo vertices are appended in first-encounter order over the owned
/// vertices' CSR-sorted neighbor lists — exactly the insertion order of
/// the materialized path's `HashMap::entry` calls — and each discovery
/// pushes `owner_rank[v]` onto `transfers[owner][fog]` immediately, so
/// both sub-CSRs and the plan are bit-identical to
/// [`extract_materialized`].
pub struct GroundingStream<'a> {
    g: &'a Graph,
    assignment: &'a [u32],
    n_fogs: usize,
    /// Owned vertex lists not yet emitted; each is moved out (not
    /// cloned) when its fog is grounded.
    owned: Vec<Vec<u32>>,
    owner_rank: Vec<u32>,
    local_of: Vec<u32>,
    transfers: Vec<Vec<Vec<u32>>>,
    next: usize,
}

impl<'a> GroundingStream<'a> {
    /// One O(V) pass: owned lists + owner ranks. No per-fog state yet.
    pub fn new(g: &'a Graph, assignment: &'a [u32], n_fogs: usize)
               -> GroundingStream<'a> {
        let nv = g.num_vertices();
        assert_eq!(assignment.len(), nv);
        let mut owned: Vec<Vec<u32>> = vec![Vec::new(); n_fogs];
        let mut owner_rank = vec![0u32; nv];
        for v in 0..nv {
            let j = assignment[v] as usize;
            owner_rank[v] = owned[j].len() as u32;
            owned[j].push(v as u32);
        }
        GroundingStream {
            g,
            assignment,
            n_fogs,
            owned,
            owner_rank,
            local_of: vec![u32::MAX; nv],
            transfers: vec![vec![Vec::new(); n_fogs]; n_fogs],
            next: 0,
        }
    }

    /// Ground the next fog's sub-CSR, or `None` when all fogs are done.
    /// The caller owns the result and may drop it before asking for the
    /// next one — that is the point.
    pub fn next_fog(&mut self) -> Option<LocalGraph> {
        if self.next == self.n_fogs {
            return None;
        }
        let j = self.next;
        self.next += 1;
        let g = self.g;
        let mut vertices = std::mem::take(&mut self.owned[j]);
        let n_local = vertices.len();
        for (i, &v) in vertices.iter().enumerate() {
            self.local_of[v as usize] = i as u32;
        }
        let mut src = Vec::new();
        let mut dst = Vec::new();
        // in-edges of owned vertices: graph is symmetric, so
        // in-neighbors == out-neighbors
        let mut li = 0;
        while li < n_local {
            let v = vertices[li];
            for &u in g.neighbors(v as usize) {
                let mut si = self.local_of[u as usize];
                if si == u32::MAX {
                    si = vertices.len() as u32;
                    vertices.push(u);
                    self.local_of[u as usize] = si;
                    let owner = self.assignment[u as usize] as usize;
                    self.transfers[owner][j]
                        .push(self.owner_rank[u as usize]);
                }
                src.push(si);
                dst.push(li as u32);
            }
            li += 1;
        }
        let global_degree = vertices
            .iter()
            .map(|&v| g.degree(v as usize) as u32)
            .collect();
        // reset the scratch for the next fog: touch only this fog's
        // entries, not all V
        for &v in &vertices {
            self.local_of[v as usize] = u32::MAX;
        }
        Some(LocalGraph { vertices, n_local, src, dst, global_degree })
    }

    /// The completed exchange plan. Must only be called after every fog
    /// has been grounded — requester rows are filled as each requester
    /// discovers its halo.
    pub fn finish(self) -> ExchangePlan {
        assert_eq!(
            self.next, self.n_fogs,
            "finish() before all fogs were grounded"
        );
        ExchangePlan { transfers: self.transfers }
    }

    /// Heap bytes of the stream's own state right now: the two flat
    /// V-sized arrays, not-yet-emitted owned lists, and the plan rows
    /// accumulated so far. Peak streamed grounding memory is
    /// `max over fogs (scratch_bytes + that fog's sub heap_bytes)`.
    pub fn scratch_bytes(&self) -> usize {
        let owned: usize = self.owned.iter().map(|v| v.len() * 4).sum();
        let plan: usize = self
            .transfers
            .iter()
            .flat_map(|row| row.iter().map(|v| v.len() * 4))
            .sum();
        self.owner_rank.len() * 4 + self.local_of.len() * 4 + owned + plan
    }
}

/// Extract per-fog local graphs + the exchange plan from an assignment
/// (assignment[v] = fog index, must be < n_fogs). Runs the streamed
/// path; callers that cannot hold every sub-CSR at once should drive
/// [`GroundingStream`] directly and drop each sub as they go.
pub fn extract(g: &Graph, assignment: &[u32], n_fogs: usize)
               -> (Vec<LocalGraph>, ExchangePlan) {
    let mut stream = GroundingStream::new(g, assignment, n_fogs);
    let mut subs = Vec::with_capacity(n_fogs);
    while let Some(sub) = stream.next_fog() {
        subs.push(sub);
    }
    (subs, stream.finish())
}

/// The original materialize-everything grounding: per-fog remap
/// `HashMap`s, cloned owned lists, and a global-id `needed` table
/// translated through per-owner index maps at the end. Kept as the
/// reference implementation for the streamed-parity gate and as the
/// "materialize all" arm of the scale bench's peak-memory comparison.
pub fn extract_materialized(g: &Graph, assignment: &[u32], n_fogs: usize)
                            -> (Vec<LocalGraph>, ExchangePlan) {
    let nv = g.num_vertices();
    assert_eq!(assignment.len(), nv);

    let mut locals: Vec<Vec<u32>> = vec![Vec::new(); n_fogs];
    for v in 0..nv {
        locals[assignment[v] as usize].push(v as u32);
    }

    let mut subs = Vec::with_capacity(n_fogs);
    // owner -> (requester -> owner-local vertex ids needed)
    let mut needed: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); n_fogs]; n_fogs];

    for (j, owned) in locals.iter().enumerate() {
        // local index mapping
        let mut index: HashMap<u32, u32> =
            owned.iter().enumerate().map(|(i, &v)| (v, i as u32)).collect();
        let mut vertices = owned.clone();
        let n_local = owned.len();
        let mut src = Vec::new();
        let mut dst = Vec::new();
        // in-edges of owned vertices: graph is symmetric, so in-neighbors
        // == out-neighbors
        for (li, &v) in owned.iter().enumerate() {
            for &u in g.neighbors(v as usize) {
                let si = *index.entry(u).or_insert_with(|| {
                    vertices.push(u);
                    (vertices.len() - 1) as u32
                });
                src.push(si);
                dst.push(li as u32);
            }
        }
        // halo ownership bookkeeping
        for &hv in &vertices[n_local..] {
            let owner = assignment[hv as usize] as usize;
            needed[owner][j].push(hv);
        }
        let global_degree =
            vertices.iter().map(|&v| g.degree(v as usize) as u32).collect();
        subs.push(LocalGraph { vertices, n_local, src, dst, global_degree });
    }

    // translate needed global ids into owner-local indices
    let mut owner_index: Vec<HashMap<u32, u32>> = Vec::with_capacity(n_fogs);
    for sub in &subs {
        owner_index.push(
            sub.vertices[..sub.n_local]
                .iter()
                .enumerate()
                .map(|(i, &v)| (v, i as u32))
                .collect(),
        );
    }
    let mut transfers = vec![vec![Vec::new(); n_fogs]; n_fogs];
    for (owner, row) in needed.into_iter().enumerate() {
        for (req, globals) in row.into_iter().enumerate() {
            transfers[owner][req] = globals
                .iter()
                .map(|gv| owner_index[owner][gv])
                .collect();
        }
    }

    (subs, ExchangePlan { transfers })
}

/// Extract a single subgraph over `vertex_set` with halo, for calibration
/// sampling (paper §III-B's proxy-guided profiling).
pub fn extract_one(g: &Graph, vertex_set: &[u32]) -> LocalGraph {
    let mut assignment = vec![1u32; g.num_vertices()];
    for &v in vertex_set {
        assignment[v as usize] = 0;
    }
    let (mut subs, _) = extract(g, &assignment, 2);
    subs.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    /// 0-1-2-3-4 path + edge 0-4, split {0,1},{2,3,4}
    fn setup() -> (Graph, Vec<LocalGraph>, ExchangePlan) {
        let g = Graph::from_undirected_edges(
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
        );
        let assignment = vec![0, 0, 1, 1, 1];
        let (subs, plan) = extract(&g, &assignment, 2);
        (g, subs, plan)
    }

    #[test]
    fn locals_and_halo_are_correct() {
        let (_, subs, _) = setup();
        assert_eq!(subs[0].n_local, 2);
        assert_eq!(&subs[0].vertices[..2], &[0, 1]);
        // fog0 needs 2 (neighbor of 1) and 4 (neighbor of 0) as halo
        let mut halo = subs[0].vertices[2..].to_vec();
        halo.sort_unstable();
        assert_eq!(halo, vec![2, 4]);
        assert_eq!(subs[1].n_local, 3);
        let mut halo1 = subs[1].vertices[3..].to_vec();
        halo1.sort_unstable();
        assert_eq!(halo1, vec![0, 1]);
    }

    #[test]
    fn all_dst_are_local_and_edges_complete() {
        let (g, subs, _) = setup();
        let mut total_edges = 0;
        for sub in &subs {
            assert!(sub.dst.iter().all(|&d| (d as usize) < sub.n_local));
            total_edges += sub.num_edges();
        }
        // every directed edge lands in exactly one fog (by destination)
        assert_eq!(total_edges, g.num_edges());
    }

    #[test]
    fn global_degrees_preserved() {
        let (g, subs, _) = setup();
        for sub in &subs {
            for (i, &v) in sub.vertices.iter().enumerate() {
                assert_eq!(
                    sub.global_degree[i] as usize,
                    g.degree(v as usize)
                );
            }
        }
    }

    #[test]
    fn exchange_plan_covers_halo() {
        let (_, subs, plan) = setup();
        // fog1 owns vertex 2 and 4; fog0's halo = {2,4} -> transfers[1][0]
        let t10: Vec<u32> = plan.transfers[1][0].clone();
        let fog1_locals = &subs[1].vertices[..subs[1].n_local];
        let shipped: Vec<u32> =
            t10.iter().map(|&li| fog1_locals[li as usize]).collect();
        let mut shipped_sorted = shipped.clone();
        shipped_sorted.sort_unstable();
        assert_eq!(shipped_sorted, vec![2, 4]);
        assert_eq!(plan.total_vertices(), 4); // {2,4} to fog0, {0,1} to fog1
    }

    #[test]
    fn single_partition_has_no_halo() {
        let g = Graph::from_undirected_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let (subs, plan) = extract(&g, &[0, 0, 0, 0], 1);
        assert_eq!(subs[0].n_halo(), 0);
        assert_eq!(plan.total_vertices(), 0);
        assert_eq!(subs[0].num_edges(), g.num_edges());
    }

    #[test]
    fn extract_one_matches_manual() {
        let g = Graph::from_undirected_edges(
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4)],
        );
        let sub = extract_one(&g, &[1, 2]);
        assert_eq!(sub.n_local, 2);
        let mut halo = sub.vertices[sub.n_local..].to_vec();
        halo.sort_unstable();
        assert_eq!(halo, vec![0, 3]);
    }

    /// The parity gate on the hand-checkable fixture; the seeded
    /// rmat/sbm/road sweep lives in tests/grounding_parity.rs.
    #[test]
    fn streamed_matches_materialized_on_fixture() {
        let g = Graph::from_undirected_edges(
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
        );
        let assignment = vec![0, 0, 1, 1, 1];
        let (s_subs, s_plan) = extract(&g, &assignment, 2);
        let (m_subs, m_plan) = extract_materialized(&g, &assignment, 2);
        assert_eq!(s_subs, m_subs);
        assert_eq!(s_plan, m_plan);
    }

    #[test]
    fn empty_fog_grounds_to_empty_sub() {
        let g = Graph::from_undirected_edges(3, &[(0, 1), (1, 2)]);
        // fog 1 owns nothing
        let (subs, plan) = extract(&g, &[0, 0, 2], 3);
        assert_eq!(subs.len(), 3);
        assert_eq!(subs[1].n_total(), 0);
        assert_eq!(subs[1].num_edges(), 0);
        let (m_subs, m_plan) = extract_materialized(&g, &[0, 0, 2], 3);
        assert_eq!(subs, m_subs);
        assert_eq!(plan, m_plan);
    }

    #[test]
    fn stream_accounting_is_consistent() {
        let (g, _) = generate::sbm(300, 1200, 3, 0.8, 11);
        let assignment: Vec<u32> =
            (0..300).map(|v| (v % 3) as u32).collect();
        let mut stream = GroundingStream::new(&g, &assignment, 3);
        // scratch starts at two V-sized arrays + all owned lists
        let base = stream.scratch_bytes();
        assert!(base >= 300 * 4 * 3);
        let mut peak_one_sub = 0usize;
        while let Some(sub) = stream.next_fog() {
            assert!(sub.heap_bytes()
                >= 4 * (sub.n_total() + 2 * sub.num_edges()));
            peak_one_sub = peak_one_sub.max(sub.heap_bytes());
        }
        let plan = stream.finish();
        assert_eq!(plan.heap_bytes(), plan.total_vertices() * 4);
        // materialized-all holds every sub at once: strictly more than
        // any single streamed sub on a 3-way split
        let (m_subs, _) = extract_materialized(&g, &assignment, 3);
        let all: usize = m_subs.iter().map(|s| s.heap_bytes()).sum();
        assert!(all > peak_one_sub);
    }

    #[test]
    #[should_panic(expected = "before all fogs")]
    fn finish_requires_all_fogs() {
        let g = Graph::from_undirected_edges(3, &[(0, 1), (1, 2)]);
        let assignment = vec![0, 0, 1];
        let mut stream = GroundingStream::new(&g, &assignment, 2);
        let _ = stream.next_fog();
        let _ = stream.finish();
    }
}
