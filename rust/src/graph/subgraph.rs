//! Partition-local subgraph extraction with halo vertices, plus the
//! cross-fog exchange plan the BSP runtime executes between GNN layers
//! (paper §III-E).
//!
//! For a data placement π, fog j owns local vertices L_j; to compute one
//! GNN layer for L_j it additionally needs the current activations of
//! every in-neighbor of L_j that lives elsewhere — the *halo* H_j. The
//! local index space is `[locals..., halo...]`, and the edge list contains
//! every edge whose destination is local (sources may be halo).

use std::collections::HashMap;

use super::csr::Graph;

/// One fog's executable view of its partition.
#[derive(Clone, Debug)]
pub struct LocalGraph {
    /// Global vertex ids; first `n_local` entries are owned, rest is halo.
    pub vertices: Vec<u32>,
    pub n_local: usize,
    /// COO edges in local index space; dst < n_local always.
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    /// Global in-degree of each local-space vertex (for GCN/SAGE
    /// normalization — must be the FULL-graph degree, not the local one).
    pub global_degree: Vec<u32>,
}

impl LocalGraph {
    pub fn n_total(&self) -> usize {
        self.vertices.len()
    }

    pub fn n_halo(&self) -> usize {
        self.vertices.len() - self.n_local
    }

    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    /// The cardinality ⟨|V|, |N_V|⟩ the profiler's latency model uses
    /// (paper §III-B): owned vertices and their one-hop neighbor count.
    pub fn cardinality(&self) -> (usize, usize) {
        (self.n_local, self.num_edges())
    }
}

/// Cross-fog halo exchange plan for one layer boundary: for each
/// (owner, requester) pair, which owner-local vertices to ship.
#[derive(Clone, Debug, Default)]
pub struct ExchangePlan {
    /// transfers[owner][requester] = owner-local indices (usize into the
    /// owner's `vertices[..n_local]`) that the requester needs.
    pub transfers: Vec<Vec<Vec<u32>>>,
}

impl ExchangePlan {
    /// Total vertices shipped in one synchronization round.
    pub fn total_vertices(&self) -> usize {
        self.transfers
            .iter()
            .flat_map(|row| row.iter().map(|v| v.len()))
            .sum()
    }
}

/// Extract per-fog local graphs + the exchange plan from an assignment
/// (assignment[v] = fog index, must be < n_fogs).
pub fn extract(g: &Graph, assignment: &[u32], n_fogs: usize)
               -> (Vec<LocalGraph>, ExchangePlan) {
    let nv = g.num_vertices();
    assert_eq!(assignment.len(), nv);

    let mut locals: Vec<Vec<u32>> = vec![Vec::new(); n_fogs];
    for v in 0..nv {
        locals[assignment[v] as usize].push(v as u32);
    }

    let mut subs = Vec::with_capacity(n_fogs);
    // owner -> (requester -> owner-local vertex ids needed)
    let mut needed: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); n_fogs]; n_fogs];

    for (j, owned) in locals.iter().enumerate() {
        // local index mapping
        let mut index: HashMap<u32, u32> =
            owned.iter().enumerate().map(|(i, &v)| (v, i as u32)).collect();
        let mut vertices = owned.clone();
        let n_local = owned.len();
        let mut src = Vec::new();
        let mut dst = Vec::new();
        // in-edges of owned vertices: graph is symmetric, so in-neighbors
        // == out-neighbors
        for (li, &v) in owned.iter().enumerate() {
            for &u in g.neighbors(v as usize) {
                let si = *index.entry(u).or_insert_with(|| {
                    vertices.push(u);
                    (vertices.len() - 1) as u32
                });
                src.push(si);
                dst.push(li as u32);
            }
        }
        // halo ownership bookkeeping
        for &hv in &vertices[n_local..] {
            let owner = assignment[hv as usize] as usize;
            needed[owner][j].push(hv);
        }
        let global_degree =
            vertices.iter().map(|&v| g.degree(v as usize) as u32).collect();
        subs.push(LocalGraph { vertices, n_local, src, dst, global_degree });
    }

    // translate needed global ids into owner-local indices
    let mut owner_index: Vec<HashMap<u32, u32>> = Vec::with_capacity(n_fogs);
    for sub in &subs {
        owner_index.push(
            sub.vertices[..sub.n_local]
                .iter()
                .enumerate()
                .map(|(i, &v)| (v, i as u32))
                .collect(),
        );
    }
    let mut transfers = vec![vec![Vec::new(); n_fogs]; n_fogs];
    for (owner, row) in needed.into_iter().enumerate() {
        for (req, globals) in row.into_iter().enumerate() {
            transfers[owner][req] = globals
                .iter()
                .map(|gv| owner_index[owner][gv])
                .collect();
        }
    }

    (subs, ExchangePlan { transfers })
}

/// Extract a single subgraph over `vertex_set` with halo, for calibration
/// sampling (paper §III-B's proxy-guided profiling).
pub fn extract_one(g: &Graph, vertex_set: &[u32]) -> LocalGraph {
    let mut assignment = vec![1u32; g.num_vertices()];
    for &v in vertex_set {
        assignment[v as usize] = 0;
    }
    let (mut subs, _) = extract(g, &assignment, 2);
    subs.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0-1-2-3-4 path + edge 0-4, split {0,1},{2,3,4}
    fn setup() -> (Graph, Vec<LocalGraph>, ExchangePlan) {
        let g = Graph::from_undirected_edges(
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
        );
        let assignment = vec![0, 0, 1, 1, 1];
        let (subs, plan) = extract(&g, &assignment, 2);
        (g, subs, plan)
    }

    #[test]
    fn locals_and_halo_are_correct() {
        let (_, subs, _) = setup();
        assert_eq!(subs[0].n_local, 2);
        assert_eq!(&subs[0].vertices[..2], &[0, 1]);
        // fog0 needs 2 (neighbor of 1) and 4 (neighbor of 0) as halo
        let mut halo = subs[0].vertices[2..].to_vec();
        halo.sort_unstable();
        assert_eq!(halo, vec![2, 4]);
        assert_eq!(subs[1].n_local, 3);
        let mut halo1 = subs[1].vertices[3..].to_vec();
        halo1.sort_unstable();
        assert_eq!(halo1, vec![0, 1]);
    }

    #[test]
    fn all_dst_are_local_and_edges_complete() {
        let (g, subs, _) = setup();
        let mut total_edges = 0;
        for sub in &subs {
            assert!(sub.dst.iter().all(|&d| (d as usize) < sub.n_local));
            total_edges += sub.num_edges();
        }
        // every directed edge lands in exactly one fog (by destination)
        assert_eq!(total_edges, g.num_edges());
    }

    #[test]
    fn global_degrees_preserved() {
        let (g, subs, _) = setup();
        for sub in &subs {
            for (i, &v) in sub.vertices.iter().enumerate() {
                assert_eq!(
                    sub.global_degree[i] as usize,
                    g.degree(v as usize)
                );
            }
        }
    }

    #[test]
    fn exchange_plan_covers_halo() {
        let (_, subs, plan) = setup();
        // fog1 owns vertex 2 and 4; fog0's halo = {2,4} -> transfers[1][0]
        let t10: Vec<u32> = plan.transfers[1][0].clone();
        let fog1_locals = &subs[1].vertices[..subs[1].n_local];
        let shipped: Vec<u32> =
            t10.iter().map(|&li| fog1_locals[li as usize]).collect();
        let mut shipped_sorted = shipped.clone();
        shipped_sorted.sort_unstable();
        assert_eq!(shipped_sorted, vec![2, 4]);
        assert_eq!(plan.total_vertices(), 4); // {2,4} to fog0, {0,1} to fog1
    }

    #[test]
    fn single_partition_has_no_halo() {
        let g = Graph::from_undirected_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let (subs, plan) = extract(&g, &[0, 0, 0, 0], 1);
        assert_eq!(subs[0].n_halo(), 0);
        assert_eq!(plan.total_vertices(), 0);
        assert_eq!(subs[0].num_edges(), g.num_edges());
    }

    #[test]
    fn extract_one_matches_manual() {
        let g = Graph::from_undirected_edges(
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4)],
        );
        let sub = extract_one(&g, &[1, 2]);
        assert_eq!(sub.n_local, 2);
        let mut halo = sub.vertices[sub.n_local..].to_vec();
        halo.sort_unstable();
        assert_eq!(halo, vec![0, 3]);
    }
}
