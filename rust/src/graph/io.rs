//! `.fgr` binary reader/writer — byte-compatible with
//! python/compile/fgio.py (the Python side documents the layout).

use std::fs;
use std::io::{self, Read, Write};
use std::path::Path;

use super::csr::Graph;

const MAGIC: &[u8; 4] = b"FGR1";

#[derive(Debug)]
pub enum FgrError {
    Io(io::Error),
    BadMagic,
    Truncated(&'static str),
}

impl std::fmt::Display for FgrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FgrError::Io(e) => write!(f, "io: {e}"),
            FgrError::BadMagic => write!(f, "bad magic (not a .fgr file)"),
            FgrError::Truncated(w) => write!(f, "truncated file: {w}"),
        }
    }
}

impl std::error::Error for FgrError {}

impl From<io::Error> for FgrError {
    fn from(e: io::Error) -> Self {
        FgrError::Io(e)
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &'static str) -> Result<&'a [u8], FgrError> {
        if self.pos + n > self.buf.len() {
            return Err(FgrError::Truncated(what));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &'static str) -> Result<u32, FgrError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &'static str) -> Result<u64, FgrError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn vec_u64(&mut self, n: usize, what: &'static str) -> Result<Vec<u64>, FgrError> {
        let raw = self.take(n * 8, what)?;
        Ok(raw.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn vec_u32(&mut self, n: usize, what: &'static str) -> Result<Vec<u32>, FgrError> {
        let raw = self.take(n * 4, what)?;
        Ok(raw.chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn vec_f32(&mut self, n: usize, what: &'static str) -> Result<Vec<f32>, FgrError> {
        let raw = self.take(n * 4, what)?;
        Ok(raw.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn vec_i32(&mut self, n: usize, what: &'static str) -> Result<Vec<i32>, FgrError> {
        let raw = self.take(n * 4, what)?;
        Ok(raw.chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

pub fn read_fgr(path: &Path) -> Result<Graph, FgrError> {
    let buf = fs::read(path)?;
    if buf.len() < 4 || &buf[..4] != MAGIC {
        return Err(FgrError::BadMagic);
    }
    let mut c = Cursor { buf: &buf, pos: 4 };
    let v = c.u32("V")? as usize;
    let e = c.u64("E")? as usize;
    let f = c.u32("F")? as usize;
    let classes = c.u32("classes")? as usize;
    let dur = c.u32("duration")? as usize;
    let flags = c.u32("flags")?;
    let indptr = c.vec_u64(v + 1, "indptr")?;
    let indices = c.vec_u32(e, "indices")?;
    let features = c.vec_f32(v * f * dur.max(1), "features")?;
    let labels = if flags & 1 != 0 {
        Some(c.vec_i32(v, "labels")?)
    } else {
        None
    };
    let coords = if flags & 2 != 0 {
        let raw = c.vec_f32(v * 2, "coords")?;
        Some(raw.chunks_exact(2).map(|p| [p[0], p[1]]).collect())
    } else {
        None
    };
    // targets (flag bit 2) are python-side only; skip if present
    Ok(Graph {
        indptr,
        indices,
        features,
        feature_dim: f,
        duration: dur.max(1),
        num_classes: classes,
        labels,
        coords,
    })
}

pub fn write_fgr(path: &Path, g: &Graph) -> Result<(), FgrError> {
    let mut out: Vec<u8> = Vec::with_capacity(
        64 + g.indptr.len() * 8 + g.indices.len() * 4 + g.features.len() * 4,
    );
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(g.num_vertices() as u32).to_le_bytes());
    out.extend_from_slice(&(g.num_edges() as u64).to_le_bytes());
    out.extend_from_slice(&(g.feature_dim as u32).to_le_bytes());
    out.extend_from_slice(&(g.num_classes as u32).to_le_bytes());
    out.extend_from_slice(&(g.duration.max(1) as u32).to_le_bytes());
    let flags: u32 = (g.labels.is_some() as u32)
        | ((g.coords.is_some() as u32) << 1);
    out.extend_from_slice(&flags.to_le_bytes());
    for x in &g.indptr {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for x in &g.indices {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for x in &g.features {
        out.extend_from_slice(&x.to_le_bytes());
    }
    if let Some(labels) = &g.labels {
        for x in labels {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    if let Some(coords) = &g.coords {
        for p in coords {
            out.extend_from_slice(&p[0].to_le_bytes());
            out.extend_from_slice(&p[1].to_le_bytes());
        }
    }
    let mut file = fs::File::create(path)?;
    file.write_all(&out)?;
    Ok(())
}

/// Read only the header (for quick dataset listings).
pub fn read_fgr_header(path: &Path) -> Result<(usize, usize, usize, usize, usize), FgrError> {
    let mut file = fs::File::open(path)?;
    let mut head = [0u8; 28];
    file.read_exact(&mut head)?;
    if &head[..4] != MAGIC {
        return Err(FgrError::BadMagic);
    }
    let mut c = Cursor { buf: &head, pos: 4 };
    Ok((
        c.u32("V")? as usize,
        c.u64("E")? as usize,
        c.u32("F")? as usize,
        c.u32("classes")? as usize,
        c.u32("duration")? as usize,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_graph() -> Graph {
        let mut g = Graph::from_undirected_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        g.feature_dim = 3;
        g.features = (0..15).map(|x| x as f32 * 0.5).collect();
        g.num_classes = 2;
        g.labels = Some(vec![0, 1, 0, 1, 1]);
        g.coords = Some(vec![[0.0, 0.0]; 5]);
        g
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("fgr_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.fgr");
        let g = sample_graph();
        write_fgr(&p, &g).unwrap();
        let g2 = read_fgr(&p).unwrap();
        assert_eq!(g2.indptr, g.indptr);
        assert_eq!(g2.indices, g.indices);
        assert_eq!(g2.features, g.features);
        assert_eq!(g2.labels, g.labels);
        assert_eq!(g2.num_classes, 2);
        let (v, e, f, c, d) = read_fgr_header(&p).unwrap();
        assert_eq!((v, e, f, c, d), (5, 6, 3, 2, 1));
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("fgr_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.fgr");
        std::fs::write(&p, b"NOPE....................").unwrap();
        assert!(matches!(read_fgr(&p), Err(FgrError::BadMagic)));
    }

    #[test]
    fn truncation_detected() {
        let dir = std::env::temp_dir().join("fgr_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.fgr");
        let g = sample_graph();
        write_fgr(&p, &g).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 10]).unwrap();
        assert!(read_fgr(&p).is_err());
    }
}
