//! Incremental topology engine — seeded churn streams, an in-place
//! delta CSR, and partition-scoped invalidation (ROADMAP item 2).
//!
//! Churn specs arrive as repeatable `--churn` CLI strings:
//!
//! ```text
//!   add-edge@rate=0.01            add ~1% of live edges per round
//!   del-edge@rate=0.005           delete ~0.5% of live edges per round
//!   add-vertex@rate=0.001,degree=3  new vertices, 3 attachments each
//!   del-vertex@rate=0.001         remove vertices with their edges
//! ```
//!
//! A [`ChurnPlan`] canonicalizes the declared specs (sorted by op) and
//! draws every mutation from a dedicated RNG stream
//! (`seed ^ CHURN_SALT`), so runs stay bit-deterministic for a fixed
//! seed, invariant under `--churn` declaration order, and an empty
//! churn list leaves every other seeded stream untouched — a
//! churn-free run is byte-identical to one on a build without this
//! module.
//!
//! [`DeltaCsr`] applies deltas in place: deleted arcs become
//! `TOMBSTONE` holes in the base CSR, added arcs go to per-vertex
//! sorted overflow rows, and periodic compaction folds both back into
//! a clean base. Live entries of a base row stay sorted, so the merged
//! neighbor walk visits neighbors in exactly the order a from-scratch
//! [`Graph::from_undirected_edges`] rebuild would store them — the
//! foundation of the engine's bit-parity contract. The
//! `n_source_edges`-style staleness witnesses (`n_dead_slots`,
//! `n_extra`, live counters, `epoch`) stay coherent through every op
//! and are re-checkable via [`DeltaCsr::check_witnesses`].
//!
//! [`TopologyEngine`] keeps the serving state — per-fog sub-CSRs, the
//! exchange plan, owner ranks, fingerprints — and after each churn
//! round re-grounds ONLY the fogs a delta actually touched
//! (structurally dirty), patches stale halo degrees on fogs that
//! merely *see* a touched vertex, reindexes only the plan rows whose
//! owner ranks moved, and leaves every other fog's state bit-preserved.
//! The parity contract: after any churn history,
//! `extract(csr.to_graph(), assignment)` equals the engine's subs and
//! plan bit-for-bit ([`TopologyEngine::parity_check`]).

use std::collections::{BTreeSet, HashMap};
use std::time::Instant;

use crate::partition::refine::{refine_boundary, BoundaryParams};
use crate::util::cli::{parse_churn_degree, parse_churn_rate};
use crate::util::json::{num, obj, Json};
use crate::util::rng::{mix64, Rng};

use super::csr::Graph;
use super::subgraph::{extract, ExchangePlan, LocalGraph};

/// Salt for the dedicated churn RNG stream: topology mutations must
/// not perturb the arrival/load/chaos streams, so an identical run
/// with no churn declared stays bit-identical.
pub const CHURN_SALT: u64 = 0xDE17_A5EE;

/// Tombstone marker for a deleted arc slot in the base CSR.
pub const TOMBSTONE: u32 = u32::MAX;

/// Bounded retries for rejection-sampled picks (live vertex, fresh
/// edge): a failed budget skips that mutation rather than spinning.
const OP_RETRIES: usize = 64;

/// Default attachment degree for `add-vertex` specs without `degree=`.
const DEFAULT_ATTACH_DEGREE: usize = 2;

// ---------------------------------------------------------------- specs

/// One churn operation class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnOp {
    AddEdge,
    DelEdge,
    AddVertex,
    DelVertex,
}

impl ChurnOp {
    pub fn name(&self) -> &'static str {
        match self {
            ChurnOp::AddEdge => "add-edge",
            ChurnOp::DelEdge => "del-edge",
            ChurnOp::AddVertex => "add-vertex",
            ChurnOp::DelVertex => "del-vertex",
        }
    }

    fn rank(&self) -> u8 {
        match self {
            ChurnOp::AddEdge => 0,
            ChurnOp::DelEdge => 1,
            ChurnOp::AddVertex => 2,
            ChurnOp::DelVertex => 3,
        }
    }
}

/// One declared churn spec: op class, per-round rate, and (for
/// `add-vertex`) the attachment degree.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChurnSpec {
    pub op: ChurnOp,
    /// Fraction of the live population (vertices for vertex ops, live
    /// undirected edges for edge ops) mutated per scheduler round.
    pub rate: f64,
    /// Attachment edges per new vertex (`add-vertex` only).
    pub degree: usize,
}

impl ChurnSpec {
    /// Parse one `--churn` spec (`op@rate=R[,degree=D]`). Errors name
    /// the offending spec and field so the CLI can exit 2 with a
    /// usable message, mirroring `FaultSpec::parse`.
    pub fn parse(spec: &str) -> Result<ChurnSpec, String> {
        let what = format!("churn spec '{spec}'");
        let (op_s, rest) = spec.split_once('@').ok_or_else(|| {
            format!(
                "{what}: expected op@rate=R[,degree=D] (ops: add-edge, \
                 del-edge, add-vertex, del-vertex)"
            )
        })?;
        let op = match op_s.trim() {
            "add-edge" => ChurnOp::AddEdge,
            "del-edge" => ChurnOp::DelEdge,
            "add-vertex" => ChurnOp::AddVertex,
            "del-vertex" => ChurnOp::DelVertex,
            other => {
                return Err(format!(
                    "{what}: unknown op '{other}' (ops: add-edge, \
                     del-edge, add-vertex, del-vertex)"
                ))
            }
        };
        let mut rate: Option<f64> = None;
        let mut degree: Option<usize> = None;
        for part in rest.split(',') {
            let (k, v) = part.split_once('=').ok_or_else(|| {
                format!("{what}: expected key=value, got '{part}'")
            })?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "rate" => {
                    if rate.is_some() {
                        return Err(format!(
                            "{what}: duplicate key 'rate'"
                        ));
                    }
                    rate = Some(parse_churn_rate(&what, v)?);
                }
                "degree" => {
                    if op != ChurnOp::AddVertex {
                        return Err(format!(
                            "{what}: 'degree=' is only valid for \
                             add-vertex"
                        ));
                    }
                    if degree.is_some() {
                        return Err(format!(
                            "{what}: duplicate key 'degree'"
                        ));
                    }
                    degree = Some(parse_churn_degree(&what, v)?);
                }
                other => {
                    return Err(format!(
                        "{what}: unknown key '{other}'"
                    ))
                }
            }
        }
        let rate =
            rate.ok_or_else(|| format!("{what}: missing 'rate='"))?;
        Ok(ChurnSpec {
            op,
            rate,
            degree: degree.unwrap_or(DEFAULT_ATTACH_DEGREE),
        })
    }
}

/// Reject duplicate op classes across a `--churn` spec list: two
/// specs for the same op are always a typo (their rates would silently
/// compound), so the CLI exits 2 instead.
pub fn validate_churn_specs(specs: &[ChurnSpec]) -> Result<(), String> {
    for (i, a) in specs.iter().enumerate() {
        if specs[..i].iter().any(|b| b.op == a.op) {
            return Err(format!(
                "duplicate --churn op '{}': declare each op at most \
                 once",
                a.op.name()
            ));
        }
    }
    Ok(())
}

// --------------------------------------------------------------- deltas

/// One applied topology mutation, as recorded by [`ChurnPlan::round`].
/// Edge endpoints are canonicalized `u < v`; vertex deltas carry the
/// attachment/removed neighbor lists so the engine can compute dirty
/// sets without re-deriving them from the CSR.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Delta {
    AddEdge(u32, u32),
    DelEdge(u32, u32),
    AddVertex { v: u32, revived: bool, nbrs: Vec<u32> },
    DelVertex { v: u32, nbrs: Vec<u32> },
}

/// Seeded, repeatable churn stream: canonicalized specs plus a
/// dedicated RNG. `round` draws and applies one scheduler period's
/// worth of mutations and returns them for the engine to absorb.
pub struct ChurnPlan {
    specs: Vec<ChurnSpec>,
    rng: Rng,
}

impl ChurnPlan {
    /// Canonicalize (sort by op class — classes are unique after
    /// [`validate_churn_specs`]) and seed the dedicated stream, so the
    /// mutation sequence is invariant under declaration order.
    pub fn new(specs: &[ChurnSpec], seed: u64) -> ChurnPlan {
        let mut specs = specs.to_vec();
        specs.sort_by_key(|s| s.op.rank());
        ChurnPlan { specs, rng: Rng::new(mix64(seed ^ CHURN_SALT)) }
    }

    /// Per-spec mutation count for one round: `max(1, floor(rate ×
    /// live))` — a declared op always fires at least once.
    fn targets(rate: f64, live: usize) -> usize {
        ((rate * live as f64).floor() as usize).max(1)
    }

    /// Pick a live vertex by bounded rejection sampling.
    fn pick_live(&mut self, csr: &DeltaCsr) -> Option<u32> {
        let nv = csr.num_vertices() as u64;
        for _ in 0..OP_RETRIES {
            let v = self.rng.below(nv) as u32;
            if csr.is_alive(v) {
                return Some(v);
            }
        }
        None
    }

    /// Draw and apply one round of mutations. Every RNG draw comes
    /// from the plan's own stream; failed rejection budgets skip the
    /// mutation rather than blocking the round.
    pub fn round(&mut self, csr: &mut DeltaCsr) -> Vec<Delta> {
        let mut deltas = Vec::new();
        for si in 0..self.specs.len() {
            let spec = self.specs[si];
            match spec.op {
                ChurnOp::AddEdge => {
                    let n = Self::targets(
                        spec.rate,
                        csr.n_live_undirected().max(1),
                    );
                    for _ in 0..n {
                        for _ in 0..OP_RETRIES {
                            let (u, v) = match (
                                self.pick_live(csr),
                                self.pick_live(csr),
                            ) {
                                (Some(u), Some(v)) => (u, v),
                                _ => break,
                            };
                            if u == v || csr.has_edge(u, v) {
                                continue;
                            }
                            csr.add_edge(u, v);
                            deltas.push(Delta::AddEdge(
                                u.min(v),
                                u.max(v),
                            ));
                            break;
                        }
                    }
                }
                ChurnOp::DelEdge => {
                    let n = Self::targets(
                        spec.rate,
                        csr.n_live_undirected().max(1),
                    );
                    for _ in 0..n {
                        for _ in 0..OP_RETRIES {
                            let u = match self.pick_live(csr) {
                                Some(u) => u,
                                None => break,
                            };
                            let d = csr.live_deg(u);
                            if d == 0 {
                                continue;
                            }
                            let k = self.rng.below(d as u64) as usize;
                            let v = csr.nth_neighbor(u, k);
                            csr.del_edge(u, v);
                            deltas.push(Delta::DelEdge(
                                u.min(v),
                                u.max(v),
                            ));
                            break;
                        }
                    }
                }
                ChurnOp::AddVertex => {
                    let n = Self::targets(
                        spec.rate,
                        csr.n_live_vertices(),
                    );
                    for _ in 0..n {
                        let (v, revived) = csr.add_vertex();
                        let mut nbrs = Vec::new();
                        for _ in 0..spec.degree {
                            for _ in 0..OP_RETRIES {
                                let u = match self.pick_live(csr) {
                                    Some(u) => u,
                                    None => break,
                                };
                                if u == v
                                    || nbrs.contains(&u)
                                    || csr.has_edge(v, u)
                                {
                                    continue;
                                }
                                csr.add_edge(v, u);
                                nbrs.push(u);
                                break;
                            }
                        }
                        deltas.push(Delta::AddVertex {
                            v,
                            revived,
                            nbrs,
                        });
                    }
                }
                ChurnOp::DelVertex => {
                    let n = Self::targets(
                        spec.rate,
                        csr.n_live_vertices(),
                    );
                    for _ in 0..n {
                        if csr.n_live_vertices() <= 2 {
                            break;
                        }
                        let v = match self.pick_live(csr) {
                            Some(v) => v,
                            None => break,
                        };
                        let nbrs = csr.del_vertex(v);
                        deltas.push(Delta::DelVertex { v, nbrs });
                    }
                }
            }
        }
        deltas
    }
}

// ------------------------------------------------------------ delta CSR

/// Symmetric CSR with in-place mutation: `TOMBSTONE` holes for
/// deletions, per-vertex sorted overflow rows for insertions, and
/// periodic compaction. Live base entries of a row stay sorted, so the
/// merged walk in [`DeltaCsr::for_neighbors`] yields neighbors in
/// exactly the sorted order of a from-scratch rebuild.
pub struct DeltaCsr {
    indptr: Vec<u64>,
    /// Base adjacency with `TOMBSTONE` holes where arcs were deleted.
    indices: Vec<u32>,
    /// Per-vertex sorted overflow of arcs added since last compaction.
    extra: Vec<Vec<u32>>,
    live_deg: Vec<u32>,
    alive: Vec<bool>,
    /// Dead vertex ids; `add_vertex` revives the smallest first so the
    /// id space stays dense under sustained join/leave churn.
    dead: BTreeSet<u32>,
    /// Mutation counter — the coarse staleness witness: any cached
    /// view stamped with an older epoch is stale by definition.
    pub epoch: u64,
    /// Staleness witnesses (the `n_source_edges` idiom): stored arcs
    /// minus dead slots plus overflow must equal live directed arcs.
    pub n_dead_slots: usize,
    pub n_extra: usize,
    n_live_vertices: usize,
    n_live_dir_edges: usize,
    pub compactions: u64,
}

impl DeltaCsr {
    pub fn from_graph(g: &Graph) -> DeltaCsr {
        let nv = g.num_vertices();
        DeltaCsr {
            indptr: g.indptr.clone(),
            indices: g.indices.clone(),
            extra: vec![Vec::new(); nv],
            live_deg: g.degrees(),
            alive: vec![true; nv],
            dead: BTreeSet::new(),
            epoch: 0,
            n_dead_slots: 0,
            n_extra: 0,
            n_live_vertices: nv,
            n_live_dir_edges: g.num_edges(),
            compactions: 0,
        }
    }

    pub fn num_vertices(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn n_live_vertices(&self) -> usize {
        self.n_live_vertices
    }

    pub fn n_live_undirected(&self) -> usize {
        self.n_live_dir_edges / 2
    }

    pub fn is_alive(&self, v: u32) -> bool {
        self.alive[v as usize]
    }

    pub fn alive_mask(&self) -> &[bool] {
        &self.alive
    }

    pub fn live_deg(&self, v: u32) -> u32 {
        self.live_deg[v as usize]
    }

    fn base_row(&self, v: u32) -> &[u32] {
        let vi = v as usize;
        &self.indices
            [self.indptr[vi] as usize..self.indptr[vi + 1] as usize]
    }

    /// Visit v's live neighbors in ascending order: a sorted merge of
    /// the live base entries (sorted, tombstones skipped) and the
    /// sorted overflow row.
    pub fn for_neighbors<F: FnMut(u32)>(&self, v: u32, mut f: F) {
        let base = self.base_row(v);
        let ex = &self.extra[v as usize];
        let (mut bi, mut ei) = (0usize, 0usize);
        loop {
            while bi < base.len() && base[bi] == TOMBSTONE {
                bi += 1;
            }
            match (bi < base.len(), ei < ex.len()) {
                (true, true) => {
                    if base[bi] <= ex[ei] {
                        f(base[bi]);
                        bi += 1;
                    } else {
                        f(ex[ei]);
                        ei += 1;
                    }
                }
                (true, false) => {
                    f(base[bi]);
                    bi += 1;
                }
                (false, true) => {
                    f(ex[ei]);
                    ei += 1;
                }
                (false, false) => break,
            }
        }
    }

    /// v's k-th live neighbor in ascending order (k < live_deg(v)).
    pub fn nth_neighbor(&self, v: u32, k: usize) -> u32 {
        let mut seen = 0usize;
        let mut found = TOMBSTONE;
        self.for_neighbors(v, |u| {
            if seen == k {
                found = u;
            }
            seen += 1;
        });
        assert_ne!(found, TOMBSTONE, "nth_neighbor({v}, {k}) past end");
        found
    }

    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        // scan from the lower-degree endpoint
        let (a, b) = if self.live_deg(u) <= self.live_deg(v) {
            (u, v)
        } else {
            (v, u)
        };
        for &x in self.base_row(a) {
            if x == b {
                return true;
            }
            if x != TOMBSTONE && x > b {
                break;
            }
        }
        self.extra[a as usize].binary_search(&b).is_ok()
    }

    /// One direction of an edge insert: sorted-insert into overflow.
    fn insert_arc(&mut self, u: u32, v: u32) {
        let row = &mut self.extra[u as usize];
        let pos = row.partition_point(|&x| x < v);
        row.insert(pos, v);
        self.n_extra += 1;
    }

    /// One direction of an edge delete: tombstone the base slot or
    /// remove the overflow entry. Panics if the arc is absent.
    fn remove_arc(&mut self, u: u32, v: u32) {
        let vi = u as usize;
        let lo = self.indptr[vi] as usize;
        let hi = self.indptr[vi + 1] as usize;
        for slot in lo..hi {
            if self.indices[slot] == v {
                self.indices[slot] = TOMBSTONE;
                self.n_dead_slots += 1;
                return;
            }
        }
        let row = &mut self.extra[vi];
        let pos = row
            .binary_search(&v)
            .unwrap_or_else(|_| panic!("remove_arc: no arc {u}->{v}"));
        row.remove(pos);
        self.n_extra -= 1;
    }

    /// Add undirected edge u—v (must be absent, endpoints alive).
    pub fn add_edge(&mut self, u: u32, v: u32) {
        debug_assert!(u != v && self.is_alive(u) && self.is_alive(v));
        debug_assert!(!self.has_edge(u, v));
        self.insert_arc(u, v);
        self.insert_arc(v, u);
        self.live_deg[u as usize] += 1;
        self.live_deg[v as usize] += 1;
        self.n_live_dir_edges += 2;
        self.epoch += 1;
    }

    /// Delete undirected edge u—v (must be present).
    pub fn del_edge(&mut self, u: u32, v: u32) {
        self.remove_arc(u, v);
        self.remove_arc(v, u);
        self.live_deg[u as usize] -= 1;
        self.live_deg[v as usize] -= 1;
        self.n_live_dir_edges -= 2;
        self.epoch += 1;
    }

    /// Add a vertex: revive the smallest dead id if any (keeping the
    /// id space dense), else append a fresh id. Returns `(id,
    /// revived)`. The new vertex starts isolated.
    pub fn add_vertex(&mut self) -> (u32, bool) {
        self.epoch += 1;
        self.n_live_vertices += 1;
        if let Some(&v) = self.dead.iter().next() {
            self.dead.remove(&v);
            self.alive[v as usize] = true;
            return (v, true);
        }
        let v = self.num_vertices() as u32;
        let end = *self.indptr.last().unwrap();
        self.indptr.push(end);
        self.extra.push(Vec::new());
        self.live_deg.push(0);
        self.alive.push(true);
        (v, false)
    }

    /// Delete a live vertex with all its incident edges; returns the
    /// (ascending) neighbors it was detached from. The id stays in the
    /// universe as a dead, degree-0 vertex until revived.
    pub fn del_vertex(&mut self, v: u32) -> Vec<u32> {
        debug_assert!(self.is_alive(v));
        let mut nbrs = Vec::with_capacity(self.live_deg(v) as usize);
        self.for_neighbors(v, |u| nbrs.push(u));
        for &u in &nbrs {
            self.del_edge(v, u);
        }
        self.alive[v as usize] = false;
        self.dead.insert(v);
        self.n_live_vertices -= 1;
        self.epoch += 1;
        nbrs
    }

    /// Fold tombstones and overflow back into a clean base CSR when
    /// they exceed half the stored arcs. Live structure (and therefore
    /// every neighbor walk) is unchanged — compaction is invisible to
    /// the parity contract.
    pub fn maybe_compact(&mut self) -> bool {
        if (self.n_dead_slots + self.n_extra) * 2
            <= self.indices.len().max(64)
        {
            return false;
        }
        let nv = self.num_vertices();
        let mut indptr = Vec::with_capacity(nv + 1);
        indptr.push(0u64);
        let mut indices = Vec::with_capacity(self.n_live_dir_edges);
        for v in 0..nv {
            self.for_neighbors(v as u32, |u| indices.push(u));
            indptr.push(indices.len() as u64);
        }
        self.indptr = indptr;
        self.indices = indices;
        for row in &mut self.extra {
            row.clear();
        }
        self.n_dead_slots = 0;
        self.n_extra = 0;
        self.compactions += 1;
        true
    }

    /// Live undirected edge pairs (u < v), ascending — the exact input
    /// a from-scratch rebuild consumes.
    pub fn live_edge_pairs(&self) -> Vec<(u32, u32)> {
        let mut pairs = Vec::with_capacity(self.n_live_undirected());
        for v in 0..self.num_vertices() as u32 {
            self.for_neighbors(v, |u| {
                if u > v {
                    pairs.push((v, u));
                }
            });
        }
        pairs
    }

    /// Materialize the current live topology as a plain [`Graph`] —
    /// the from-scratch arm of the parity gate.
    pub fn to_graph(&self) -> Graph {
        Graph::from_undirected_edges(
            self.num_vertices(),
            &self.live_edge_pairs(),
        )
    }

    /// Recount everything and compare against the incremental
    /// witnesses — O(V+E), for tests and the experiment's gates.
    pub fn check_witnesses(&self) -> Result<(), String> {
        let nv = self.num_vertices();
        let alive_n = self.alive.iter().filter(|&&a| a).count();
        if alive_n != self.n_live_vertices {
            return Err(format!(
                "live-vertex witness {} != recount {alive_n}",
                self.n_live_vertices
            ));
        }
        if self.dead.len() != nv - alive_n {
            return Err("dead set size mismatch".into());
        }
        let mut dir = 0usize;
        let mut dead_slots = 0usize;
        let mut extra_n = 0usize;
        for v in 0..nv as u32 {
            let mut deg = 0u32;
            let mut prev: i64 = -1;
            self.for_neighbors(v, |u| {
                deg += 1;
                assert!(
                    (u as i64) > prev,
                    "row {v} not strictly ascending"
                );
                prev = u as i64;
            });
            if deg != self.live_deg(v) {
                return Err(format!(
                    "live_deg[{v}]={} != walk {deg}",
                    self.live_deg(v)
                ));
            }
            if !self.is_alive(v) && deg != 0 {
                return Err(format!("dead vertex {v} has edges"));
            }
            dir += deg as usize;
            dead_slots += self
                .base_row(v)
                .iter()
                .filter(|&&x| x == TOMBSTONE)
                .count();
            extra_n += self.extra[v as usize].len();
        }
        if dir != self.n_live_dir_edges {
            return Err(format!(
                "dir-edge witness {} != recount {dir}",
                self.n_live_dir_edges
            ));
        }
        if dead_slots != self.n_dead_slots || extra_n != self.n_extra {
            return Err(format!(
                "slot witnesses ({}, {}) != recount ({dead_slots}, \
                 {extra_n})",
                self.n_dead_slots, self.n_extra
            ));
        }
        if self.indices.len() - dead_slots + extra_n != dir {
            return Err("stored-arc balance violated".into());
        }
        Ok(())
    }
}

// --------------------------------------------------------------- engine

/// Cumulative invalidation counters — the evidence that untouched
/// partitions did zero re-grounding work (BENCH_churn.json surfaces
/// them verbatim).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct InvalidationStats {
    pub rounds: u64,
    pub deltas_applied: u64,
    pub migrations: u64,
    /// Fog-rounds fully re-grounded (structurally dirty).
    pub fogs_reground: u64,
    /// Fog-rounds whose only write was a halo-degree patch.
    pub fogs_degree_patched: u64,
    /// Fog-rounds left bit-identical: no re-ground, no patch, no
    /// plan-row write.
    pub fogs_preserved: u64,
    /// Exchange-plan rows recomputed for preserved requesters because
    /// a dirty owner's local ranks moved.
    pub plan_rows_reindexed: u64,
    /// Rounds in which at least one fog was preserved — the partial
    /// re-ground witness the CI smoke asserts on.
    pub partial_rounds: u64,
    pub compactions: u64,
}

impl InvalidationStats {
    pub fn json(&self) -> Json {
        obj(&[
            ("rounds", num(self.rounds as f64)),
            ("deltas_applied", num(self.deltas_applied as f64)),
            ("migrations", num(self.migrations as f64)),
            ("fogs_reground", num(self.fogs_reground as f64)),
            (
                "fogs_degree_patched",
                num(self.fogs_degree_patched as f64),
            ),
            ("fogs_preserved", num(self.fogs_preserved as f64)),
            (
                "plan_rows_reindexed",
                num(self.plan_rows_reindexed as f64),
            ),
            ("partial_rounds", num(self.partial_rounds as f64)),
            ("compactions", num(self.compactions as f64)),
        ])
    }
}

/// What one absorbed round touched.
#[derive(Clone, Debug, Default)]
pub struct RoundReport {
    pub deltas: usize,
    pub migrations: usize,
    /// Fogs fully re-grounded this round, ascending.
    pub dirty: Vec<u32>,
    /// Fogs whose only write was a halo-degree patch, ascending.
    pub patched: Vec<u32>,
    /// Fogs left bit-identical this round.
    pub preserved: usize,
    /// Wall seconds spent applying deltas + partial re-grounding.
    pub apply_s: f64,
}

/// End-of-run churn summary for loadtest reports: final topology plus
/// the cumulative invalidation counters. Serialized only when churn
/// was actually requested, so churn-free reports stay byte-identical.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChurnSummary {
    pub final_vertices: usize,
    pub final_live_vertices: usize,
    pub final_edges: usize,
    pub stats: InvalidationStats,
}

impl ChurnSummary {
    pub fn json(&self) -> Json {
        obj(&[
            ("final_vertices", num(self.final_vertices as f64)),
            (
                "final_live_vertices",
                num(self.final_live_vertices as f64),
            ),
            ("final_edges", num(self.final_edges as f64)),
            ("invalidation", self.stats.json()),
        ])
    }
}

/// The incremental topology engine: a [`DeltaCsr`] plus the serving
/// state derived from it — per-fog sub-CSRs, the exchange plan, owner
/// ranks and per-fog topology fingerprints — kept coherent under churn
/// by partition-scoped invalidation instead of full rebuilds.
pub struct TopologyEngine {
    pub csr: DeltaCsr,
    pub n_fogs: usize,
    /// Owner fog of every vertex ever created (dead vertices keep
    /// their last owner, exactly like a from-scratch extract over the
    /// rebuilt graph, where they appear as isolated owned vertices).
    pub assignment: Vec<u32>,
    pub subs: Vec<LocalGraph>,
    pub plan: ExchangePlan,
    /// fnv1a64 over each sub's full contents; preserved fogs keep
    /// their fingerprint bit-for-bit.
    pub fingerprints: Vec<u64>,
    pub stats: InvalidationStats,
    /// Owned vertex ids per fog, ascending — the from-scratch local
    /// order, maintained incrementally.
    locals: Vec<Vec<u32>>,
    owner_rank: Vec<u32>,
    /// Per fog: halo global id → absolute index into sub.vertices.
    halo_pos: Vec<HashMap<u32, u32>>,
    /// Per fog: sorted unique owner fogs of its halo — lets the plan
    /// reindex skip requesters with no stake in any dirty owner.
    halo_owners: Vec<Vec<u32>>,
    /// Scratch: global id → current fog-local index (MAX = absent).
    local_of: Vec<u32>,
}

impl TopologyEngine {
    /// Ground the initial topology. `assignment[v]` must be a valid
    /// fog index for every vertex.
    pub fn new(g: &Graph, assignment: &[u32], n_fogs: usize)
               -> TopologyEngine {
        let (subs, plan) = extract(g, assignment, n_fogs);
        let nv = g.num_vertices();
        let mut locals: Vec<Vec<u32>> = vec![Vec::new(); n_fogs];
        let mut owner_rank = vec![0u32; nv];
        for v in 0..nv {
            let j = assignment[v] as usize;
            owner_rank[v] = locals[j].len() as u32;
            locals[j].push(v as u32);
        }
        let mut halo_pos = Vec::with_capacity(n_fogs);
        let mut halo_owners = Vec::with_capacity(n_fogs);
        for sub in &subs {
            let mut pos = HashMap::new();
            let mut owners: Vec<u32> = Vec::new();
            for (i, &hv) in
                sub.vertices[sub.n_local..].iter().enumerate()
            {
                pos.insert(hv, (sub.n_local + i) as u32);
                let o = assignment[hv as usize];
                if let Err(p) = owners.binary_search(&o) {
                    owners.insert(p, o);
                }
            }
            halo_pos.push(pos);
            halo_owners.push(owners);
        }
        let fingerprints = subs.iter().map(LocalGraph::fingerprint).collect();
        TopologyEngine {
            csr: DeltaCsr::from_graph(g),
            n_fogs,
            assignment: assignment.to_vec(),
            subs,
            plan,
            fingerprints,
            stats: InvalidationStats::default(),
            locals,
            owner_rank,
            halo_pos,
            halo_owners,
            local_of: vec![u32::MAX; nv],
        }
    }

    /// Per-fog ⟨owned vertices, in-edges⟩ — exactly what
    /// `diffusion::estimate_times` recounts from a static graph, so
    /// the rescheduler can consume churn-induced skew without one.
    pub fn cardinalities(&self) -> Vec<(usize, usize)> {
        (0..self.n_fogs)
            .map(|j| (self.locals[j].len(), self.subs[j].num_edges()))
            .collect()
    }

    /// Draw one churn round from `plan`, apply it in place, and
    /// re-ground only what it touched.
    pub fn churn_round(&mut self, plan: &mut ChurnPlan) -> RoundReport {
        let t0 = Instant::now();
        let deltas = plan.round(&mut self.csr);
        let mut report = self.integrate(&deltas);
        report.apply_s = t0.elapsed().as_secs_f64();
        report
    }

    /// Owner for a vertex appended by `add-vertex`: plurality owner of
    /// its attachment neighbors (tie → lowest fog); with no
    /// attachments, the lightest fog (tie → lowest fog).
    fn choose_owner(&self, nbrs: &[u32]) -> u32 {
        if nbrs.is_empty() {
            let mut best = 0usize;
            for j in 1..self.n_fogs {
                if self.locals[j].len() < self.locals[best].len() {
                    best = j;
                }
            }
            return best as u32;
        }
        let mut count = vec![0usize; self.n_fogs];
        for &u in nbrs {
            count[self.assignment[u as usize] as usize] += 1;
        }
        let mut best = 0usize;
        for j in 1..self.n_fogs {
            if count[j] > count[best] {
                best = j;
            }
        }
        best as u32
    }

    /// Absorb a batch of applied deltas: grow the universe, compute
    /// the structural dirty set, run the boundary-only refinement over
    /// delta-adjacent vertices, then partial re-ground.
    pub fn integrate(&mut self, deltas: &[Delta]) -> RoundReport {
        let mut dirty = vec![false; self.n_fogs];
        let mut touched: Vec<u32> = Vec::new();
        let mut cands: Vec<u32> = Vec::new();
        for d in deltas {
            match d {
                Delta::AddVertex { v, revived, nbrs } => {
                    if !revived {
                        debug_assert_eq!(
                            *v as usize,
                            self.assignment.len()
                        );
                        let owner = self.choose_owner(nbrs);
                        self.assignment.push(owner);
                        // largest id so far: push keeps the list sorted
                        self.locals[owner as usize].push(*v);
                        self.owner_rank.push(
                            (self.locals[owner as usize].len() - 1)
                                as u32,
                        );
                        self.local_of.push(u32::MAX);
                    }
                    dirty[self.assignment[*v as usize] as usize] = true;
                    touched.push(*v);
                    cands.push(*v);
                    for &u in nbrs {
                        dirty[self.assignment[u as usize] as usize] =
                            true;
                        touched.push(u);
                        cands.push(u);
                    }
                }
                Delta::DelVertex { v, nbrs } => {
                    dirty[self.assignment[*v as usize] as usize] = true;
                    for &u in nbrs {
                        dirty[self.assignment[u as usize] as usize] =
                            true;
                        touched.push(u);
                        cands.push(u);
                    }
                }
                Delta::AddEdge(u, v) | Delta::DelEdge(u, v) => {
                    dirty[self.assignment[*u as usize] as usize] = true;
                    dirty[self.assignment[*v as usize] as usize] = true;
                    touched.push(*u);
                    touched.push(*v);
                    cands.push(*u);
                    cands.push(*v);
                }
            }
        }
        cands.sort_unstable();
        cands.dedup();
        // boundary-only refinement: delta-adjacent vertices may hop
        // between dirty partitions when that cuts their external edges
        let csr = &self.csr;
        let moves = refine_boundary(
            csr.num_vertices(),
            |v, buf| {
                buf.clear();
                csr.for_neighbors(v, |u| buf.push(u));
            },
            csr.alive_mask(),
            &mut self.assignment,
            self.n_fogs,
            &cands,
            &dirty,
            &BoundaryParams::default(),
        );
        for &(v, from, to) in &moves {
            debug_assert!(dirty[from as usize] && dirty[to as usize]);
            let row = &mut self.locals[from as usize];
            let p = row.binary_search(&v).expect("move src not owned");
            row.remove(p);
            let row = &mut self.locals[to as usize];
            let p = row.binary_search(&v).unwrap_err();
            row.insert(p, v);
        }
        let (dirty_list, patched) = self.refresh(&dirty, &touched);
        self.csr.maybe_compact();
        let preserved =
            self.n_fogs - dirty_list.len() - patched.len();
        self.stats.rounds += 1;
        self.stats.deltas_applied += deltas.len() as u64;
        self.stats.migrations += moves.len() as u64;
        self.stats.fogs_reground += dirty_list.len() as u64;
        self.stats.fogs_degree_patched += patched.len() as u64;
        self.stats.fogs_preserved += preserved as u64;
        self.stats.partial_rounds += (preserved > 0) as u64;
        self.stats.compactions = self.csr.compactions;
        RoundReport {
            deltas: deltas.len(),
            migrations: moves.len(),
            dirty: dirty_list,
            patched,
            preserved,
            apply_s: 0.0,
        }
    }

    /// Absorb an assignment produced outside the engine (the
    /// rescheduler's diffusion moves): diff against the current one,
    /// mark both ends of every move dirty, and partial re-ground.
    pub fn sync_assignment(&mut self, new_assignment: &[u32])
                           -> RoundReport {
        assert_eq!(new_assignment.len(), self.assignment.len());
        let mut dirty = vec![false; self.n_fogs];
        let mut moves = 0usize;
        for v in 0..new_assignment.len() {
            let (from, to) =
                (self.assignment[v], new_assignment[v]);
            if from == to {
                continue;
            }
            moves += 1;
            dirty[from as usize] = true;
            dirty[to as usize] = true;
            let row = &mut self.locals[from as usize];
            let p = row
                .binary_search(&(v as u32))
                .expect("sync: move src not owned");
            row.remove(p);
            let row = &mut self.locals[to as usize];
            let p = row.binary_search(&(v as u32)).unwrap_err();
            row.insert(p, v as u32);
            self.assignment[v] = to;
        }
        if moves == 0 {
            return RoundReport {
                preserved: self.n_fogs,
                ..RoundReport::default()
            };
        }
        let (dirty_list, patched) = self.refresh(&dirty, &[]);
        let preserved =
            self.n_fogs - dirty_list.len() - patched.len();
        self.stats.migrations += moves as u64;
        self.stats.fogs_reground += dirty_list.len() as u64;
        self.stats.fogs_preserved += preserved as u64;
        RoundReport {
            deltas: 0,
            migrations: moves,
            dirty: dirty_list,
            patched,
            preserved,
            apply_s: 0.0,
        }
    }

    /// Partition-scoped refresh: re-ground dirty fogs (mirroring
    /// `GroundingStream::next_fog` bit-for-bit over the delta CSR),
    /// reindex preserved requesters' plan rows whose dirty owners'
    /// ranks moved, and patch stale halo degrees on fogs that only
    /// *see* a touched vertex. Returns (dirty, patched) fog lists.
    fn refresh(&mut self, dirty: &[bool], touched: &[u32])
               -> (Vec<u32>, Vec<u32>) {
        let TopologyEngine {
            csr,
            n_fogs,
            assignment,
            subs,
            plan,
            fingerprints,
            stats,
            locals,
            owner_rank,
            halo_pos,
            halo_owners,
            local_of,
            ..
        } = self;
        let n_fogs = *n_fogs;
        // owner ranks of dirty fogs (preserved lists never change)
        for j in 0..n_fogs {
            if dirty[j] {
                for (i, &v) in locals[j].iter().enumerate() {
                    owner_rank[v as usize] = i as u32;
                }
            }
        }
        // dirty requesters rebuild every one of their plan rows
        for r in 0..n_fogs {
            if dirty[r] {
                for o in 0..n_fogs {
                    plan.transfers[o][r].clear();
                }
            }
        }
        // re-ground dirty fogs ascending — the from-scratch fog order
        for j in 0..n_fogs {
            if !dirty[j] {
                continue;
            }
            let mut vertices = locals[j].clone();
            let n_local = vertices.len();
            for (i, &v) in vertices.iter().enumerate() {
                local_of[v as usize] = i as u32;
            }
            let mut src = Vec::new();
            let mut dst = Vec::new();
            let mut li = 0usize;
            while li < n_local {
                let v = vertices[li];
                csr.for_neighbors(v, |u| {
                    let mut si = local_of[u as usize];
                    if si == u32::MAX {
                        si = vertices.len() as u32;
                        vertices.push(u);
                        local_of[u as usize] = si;
                        let owner = assignment[u as usize] as usize;
                        plan.transfers[owner][j]
                            .push(owner_rank[u as usize]);
                    }
                    src.push(si);
                    dst.push(li as u32);
                });
                li += 1;
            }
            let global_degree = vertices
                .iter()
                .map(|&v| csr.live_deg(v))
                .collect();
            for &v in &vertices {
                local_of[v as usize] = u32::MAX;
            }
            let mut pos = HashMap::new();
            let mut owners: Vec<u32> = Vec::new();
            for (i, &hv) in vertices[n_local..].iter().enumerate() {
                pos.insert(hv, (n_local + i) as u32);
                let o = assignment[hv as usize];
                if let Err(p) = owners.binary_search(&o) {
                    owners.insert(p, o);
                }
            }
            halo_pos[j] = pos;
            halo_owners[j] = owners;
            subs[j] =
                LocalGraph { vertices, n_local, src, dst, global_degree };
        }
        // preserved requesters: rows owned by dirty fogs must be
        // recomputed (owner ranks moved); halo order itself is stable
        for r in 0..n_fogs {
            if dirty[r]
                || !halo_owners[r]
                    .iter()
                    .any(|&o| dirty[o as usize])
            {
                continue;
            }
            let sub = &subs[r];
            let mut rows: Vec<Vec<u32>> = vec![Vec::new(); n_fogs];
            let mut owners: Vec<u32> = Vec::new();
            for &hv in &sub.vertices[sub.n_local..] {
                let o = assignment[hv as usize];
                rows[o as usize].push(owner_rank[hv as usize]);
                if let Err(p) = owners.binary_search(&o) {
                    owners.insert(p, o);
                }
            }
            for o in 0..n_fogs {
                if dirty[o] {
                    stats.plan_rows_reindexed += 1;
                    plan.transfers[o][r] =
                        std::mem::take(&mut rows[o]);
                }
            }
            halo_owners[r] = owners;
        }
        // degree patches: preserved fogs seeing a touched vertex only
        // in halo update that one u32 in place
        let mut patched_mask = vec![false; n_fogs];
        let mut uniq = if dirty.iter().all(|&d| d) {
            Vec::new() // every fog re-grounds; nothing left to patch
        } else {
            touched.to_vec()
        };
        uniq.sort_unstable();
        uniq.dedup();
        for &u in &uniq {
            let deg = csr.live_deg(u);
            csr.for_neighbors(u, |w| {
                let r = assignment[w as usize] as usize;
                if !dirty[r] {
                    if let Some(&p) = halo_pos[r].get(&u) {
                        if subs[r].global_degree[p as usize] != deg {
                            subs[r].global_degree[p as usize] = deg;
                            patched_mask[r] = true;
                        }
                    }
                }
            });
        }
        let dirty_list: Vec<u32> = (0..n_fogs as u32)
            .filter(|&j| dirty[j as usize])
            .collect();
        let patched: Vec<u32> = (0..n_fogs as u32)
            .filter(|&j| patched_mask[j as usize])
            .collect();
        for &j in dirty_list.iter().chain(patched.iter()) {
            fingerprints[j as usize] =
                subs[j as usize].fingerprint();
        }
        (dirty_list, patched)
    }

    /// Per-fog owned-vertex and full-graph-degree rows, ascending —
    /// exactly what `CollectionIndex::build` would recount from the
    /// rebuilt graph, ready for `CollectionIndex::from_parts`. Dead
    /// vertices stay in their owner's row with degree 0, matching the
    /// from-scratch sweep over the rebuilt (isolated-vertex) graph.
    pub fn collection_rows(&self)
                           -> (Vec<Vec<u32>>, Vec<Vec<u64>>) {
        let by_fog = self.locals.clone();
        let degrees = self
            .locals
            .iter()
            .map(|row| {
                row.iter()
                    .map(|&v| self.csr.live_deg(v) as u64)
                    .collect()
            })
            .collect();
        (by_fog, degrees)
    }

    /// End-of-run summary for reports.
    pub fn summary(&self) -> ChurnSummary {
        ChurnSummary {
            final_vertices: self.csr.num_vertices(),
            final_live_vertices: self.csr.n_live_vertices(),
            final_edges: self.csr.n_live_undirected(),
            stats: self.stats,
        }
    }

    /// The full bit-parity gate: rebuild the live topology from
    /// scratch, extract with the engine's assignment, and demand
    /// identical subs, plan, and fingerprints.
    pub fn parity_check(&self) -> Result<(), String> {
        self.csr.check_witnesses()?;
        let rebuilt = self.csr.to_graph();
        let (subs, plan) =
            extract(&rebuilt, &self.assignment, self.n_fogs);
        for j in 0..self.n_fogs {
            if subs[j] != self.subs[j] {
                return Err(format!(
                    "fog {j}: incremental sub != from-scratch sub"
                ));
            }
            if self.fingerprints[j] != subs[j].fingerprint() {
                return Err(format!("fog {j}: stale fingerprint"));
            }
        }
        if plan != self.plan {
            return Err(
                "incremental plan != from-scratch plan".into()
            );
        }
        Ok(())
    }
}

/// One deterministic BSP neighbor-sum round over grounded state: local
/// rows come from `features` (global order), halo rows arrive through
/// the exchange plan, and each fog accumulates `out[dst] += state[src]`
/// in stored edge order. Returns per-vertex sums in global order — the
/// served-output arm of the parity gate (identical subs + plan must
/// produce bitwise-identical f32 outputs).
pub fn bsp_aggregate(
    subs: &[LocalGraph],
    plan: &ExchangePlan,
    assignment: &[u32],
    features: &[f32],
    dims: usize,
) -> Vec<f32> {
    let n_fogs = subs.len();
    let nv = features.len() / dims;
    // owned rows, per fog, from the global feature table
    let owned: Vec<Vec<f32>> = subs
        .iter()
        .map(|s| {
            let mut rows = vec![0.0f32; s.n_local * dims];
            for (i, &v) in s.vertices[..s.n_local].iter().enumerate() {
                rows[i * dims..(i + 1) * dims].copy_from_slice(
                    &features[v as usize * dims..][..dims],
                );
            }
            rows
        })
        .collect();
    let mut out = vec![0.0f32; nv * dims];
    for (r, sub) in subs.iter().enumerate() {
        let mut state = vec![0.0f32; sub.n_total() * dims];
        state[..sub.n_local * dims].copy_from_slice(&owned[r]);
        // halo rows: consume each owner's plan row in discovery order
        let mut cursor = vec![0usize; n_fogs];
        for h in sub.n_local..sub.n_total() {
            let u = sub.vertices[h] as usize;
            let o = assignment[u] as usize;
            let lrank = plan.transfers[o][r][cursor[o]] as usize;
            cursor[o] += 1;
            state[h * dims..(h + 1) * dims].copy_from_slice(
                &owned[o][lrank * dims..(lrank + 1) * dims],
            );
        }
        for e in 0..sub.num_edges() {
            let s = sub.src[e] as usize;
            let d = sub.vertices[sub.dst[e] as usize] as usize;
            for k in 0..dims {
                out[d * dims + k] += state[s * dims + k];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    fn spec(s: &str) -> ChurnSpec {
        ChurnSpec::parse(s).unwrap()
    }

    #[test]
    fn parses_valid_specs() {
        let s = spec("add-edge@rate=0.01");
        assert_eq!(s.op, ChurnOp::AddEdge);
        assert_eq!(s.rate, 0.01);
        let s = spec("add-vertex@rate=0.001,degree=5");
        assert_eq!(s.op, ChurnOp::AddVertex);
        assert_eq!(s.degree, 5);
        assert_eq!(spec("add-vertex@rate=0.1").degree, 2);
        assert_eq!(spec("del-vertex@rate=0.5").op, ChurnOp::DelVertex);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "add-edge",                      // no @
            "grow@rate=0.1",                 // unknown op
            "add-edge@rate",                 // no key=value
            "add-edge@rate=0",               // zero rate
            "add-edge@rate=0.6",             // rate > 0.5
            "add-edge@rate=nan",             // non-finite
            "add-edge@rate=0.1,rate=0.2",    // duplicate key
            "add-edge@rate=0.1,degree=2",    // degree on non-add-vertex
            "add-vertex@rate=0.1,degree=0",  // zero degree
            "add-vertex@rate=0.1,degree=65", // absurd degree
            "add-edge@rate=0.1,burst=2",     // unknown key
            "add-edge@",                     // empty body
            "del-edge@degree=2",             // missing rate
        ] {
            let e = ChurnSpec::parse(bad);
            assert!(e.is_err(), "{bad:?} accepted");
            assert!(e.unwrap_err().contains(bad), "{bad:?} not named");
        }
    }

    #[test]
    fn duplicate_ops_rejected_across_specs() {
        let a = spec("add-edge@rate=0.1");
        let b = spec("del-edge@rate=0.1");
        assert!(validate_churn_specs(&[a, b]).is_ok());
        let e = validate_churn_specs(&[a, b, spec("add-edge@rate=0.2")]);
        assert!(e.unwrap_err().contains("add-edge"));
    }

    fn ring(n: usize) -> Graph {
        let edges: Vec<(u32, u32)> = (0..n as u32)
            .map(|v| (v, (v + 1) % n as u32))
            .map(|(a, b)| (a.min(b), a.max(b)))
            .collect();
        Graph::from_undirected_edges(n, &edges)
    }

    #[test]
    fn delta_csr_edge_ops_round_trip() {
        let g = ring(8);
        let mut csr = DeltaCsr::from_graph(&g);
        assert!(csr.has_edge(0, 1));
        assert!(!csr.has_edge(0, 2));
        csr.add_edge(0, 2);
        csr.del_edge(0, 1);
        csr.check_witnesses().unwrap();
        let rebuilt = csr.to_graph();
        assert_eq!(rebuilt.neighbors(0), &[2, 7]);
        assert_eq!(rebuilt.neighbors(2), &[0, 1, 3]);
        // delete-then-re-add of the same edge restores the original
        csr.del_edge(0, 2);
        csr.add_edge(0, 1);
        csr.check_witnesses().unwrap();
        let back = csr.to_graph();
        assert_eq!(back.indptr, g.indptr);
        assert_eq!(back.indices, g.indices);
    }

    #[test]
    fn delta_csr_vertex_ops_and_revival() {
        let g = ring(6);
        let mut csr = DeltaCsr::from_graph(&g);
        let nbrs = csr.del_vertex(2);
        assert_eq!(nbrs, vec![1, 3]);
        assert_eq!(csr.n_live_vertices(), 5);
        assert_eq!(csr.live_deg(2), 0);
        csr.check_witnesses().unwrap();
        // revival hands back the smallest dead id
        let (v, revived) = csr.add_vertex();
        assert_eq!((v, revived), (2, true));
        csr.add_edge(2, 1);
        csr.add_edge(2, 3);
        csr.check_witnesses().unwrap();
        let back = csr.to_graph();
        assert_eq!(back.indptr, g.indptr);
        assert_eq!(back.indices, g.indices);
        // appending past the universe grows it
        let (w, revived) = csr.add_vertex();
        assert_eq!((w, revived), (6, false));
        csr.add_edge(6, 0);
        assert_eq!(csr.num_vertices(), 7);
        csr.check_witnesses().unwrap();
    }

    #[test]
    fn compaction_is_invisible_to_live_structure() {
        let (g, _) = generate::sbm(120, 480, 3, 0.8, 7);
        let mut csr = DeltaCsr::from_graph(&g);
        let mut plan = ChurnPlan::new(
            &[spec("add-edge@rate=0.2"), spec("del-edge@rate=0.2")],
            99,
        );
        let mut compacted = false;
        for _ in 0..40 {
            plan.round(&mut csr);
            let before = csr.to_graph();
            if csr.maybe_compact() {
                compacted = true;
                let after = csr.to_graph();
                assert_eq!(before.indptr, after.indptr);
                assert_eq!(before.indices, after.indices);
                assert_eq!(csr.n_dead_slots, 0);
                assert_eq!(csr.n_extra, 0);
            }
            csr.check_witnesses().unwrap();
        }
        assert!(compacted, "fixture never triggered compaction");
        assert!(csr.compactions > 0);
    }

    #[test]
    fn churn_plan_is_deterministic_and_order_invariant() {
        let specs_a = [
            spec("add-edge@rate=0.05"),
            spec("del-vertex@rate=0.02"),
            spec("add-vertex@rate=0.02,degree=3"),
        ];
        let specs_b = [specs_a[2], specs_a[0], specs_a[1]];
        let g = generate::rmat(256, 1024, 7, (0.57, 0.19, 0.19, 0.05));
        let run = |specs: &[ChurnSpec]| {
            let mut csr = DeltaCsr::from_graph(&g);
            let mut plan = ChurnPlan::new(specs, 42);
            let mut all = Vec::new();
            for _ in 0..5 {
                all.extend(plan.round(&mut csr));
            }
            let final_g = csr.to_graph();
            (all, final_g.indptr, final_g.indices)
        };
        let a = run(&specs_a);
        let b = run(&specs_b);
        assert_eq!(a, b, "declaration order leaked into the stream");
    }

    fn engine_fixture(
        nv: usize,
        ne: usize,
        n_fogs: usize,
        seed: u64,
    ) -> TopologyEngine {
        let g = generate::rmat(nv, ne, 7, (0.57, 0.19, 0.19, 0.05));
        let assignment: Vec<u32> = (0..nv)
            .map(|v| {
                (mix64(seed ^ v as u64) % n_fogs as u64) as u32
            })
            .collect();
        TopologyEngine::new(&g, &assignment, n_fogs)
    }

    #[test]
    fn engine_holds_parity_under_mixed_churn() {
        for &(n_fogs, seed) in &[(3usize, 11u64), (5, 23)] {
            let mut eng = engine_fixture(200, 800, n_fogs, seed);
            let mut plan = ChurnPlan::new(
                &[
                    spec("add-edge@rate=0.03"),
                    spec("del-edge@rate=0.03"),
                    spec("add-vertex@rate=0.02,degree=3"),
                    spec("del-vertex@rate=0.02"),
                ],
                seed,
            );
            for round in 0..6 {
                let rep = eng.churn_round(&mut plan);
                assert!(rep.deltas > 0);
                eng.parity_check().unwrap_or_else(|e| {
                    panic!("round {round} (fogs {n_fogs}): {e}")
                });
            }
            assert!(eng.stats.deltas_applied > 0);
        }
    }

    #[test]
    fn trickle_churn_preserves_untouched_fogs_bitwise() {
        let mut eng = engine_fixture(400, 1200, 8, 3);
        let mut plan =
            ChurnPlan::new(&[spec("del-edge@rate=0.001")], 3);
        let before_subs = eng.subs.clone();
        let before_fp = eng.fingerprints.clone();
        let rep = eng.churn_round(&mut plan);
        assert!(rep.preserved > 0, "trickle round preserved nothing");
        for j in 0..8u32 {
            if !rep.dirty.contains(&j) && !rep.patched.contains(&j) {
                assert_eq!(
                    eng.subs[j as usize], before_subs[j as usize],
                    "preserved fog {j} was touched"
                );
                assert_eq!(
                    eng.fingerprints[j as usize],
                    before_fp[j as usize]
                );
            }
        }
        assert_eq!(eng.stats.partial_rounds, 1);
        eng.parity_check().unwrap();
    }

    #[test]
    fn served_outputs_match_rebuilt_bitwise() {
        let dims = 4usize;
        let mut eng = engine_fixture(150, 600, 4, 17);
        let mut plan = ChurnPlan::new(
            &[
                spec("add-edge@rate=0.05"),
                spec("add-vertex@rate=0.03,degree=2"),
            ],
            17,
        );
        for _ in 0..4 {
            eng.churn_round(&mut plan);
        }
        let mut rng = Rng::new(5);
        let feats: Vec<f32> = (0..eng.csr.num_vertices() * dims)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        let inc = bsp_aggregate(
            &eng.subs, &eng.plan, &eng.assignment, &feats, dims,
        );
        let rebuilt = eng.csr.to_graph();
        let (subs, plan2) =
            extract(&rebuilt, &eng.assignment, eng.n_fogs);
        let full = bsp_aggregate(
            &subs, &plan2, &eng.assignment, &feats, dims,
        );
        assert_eq!(inc.len(), full.len());
        assert!(
            inc.iter()
                .zip(&full)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "served outputs diverged bitwise"
        );
    }

    #[test]
    fn sync_assignment_absorbs_external_moves() {
        let mut eng = engine_fixture(120, 480, 4, 29);
        let mut asn = eng.assignment.clone();
        // migrate a handful of vertices, fog 3 untouched
        for v in [0usize, 7, 19, 44] {
            if asn[v] != 3 {
                asn[v] = (asn[v] + 1) % 3;
            }
        }
        let fp3 = eng.fingerprints[3];
        let rep = eng.sync_assignment(&asn);
        assert!(rep.migrations > 0);
        assert_eq!(eng.assignment, asn);
        eng.parity_check().unwrap();
        if !rep.dirty.contains(&3) && !rep.patched.contains(&3) {
            assert_eq!(eng.fingerprints[3], fp3);
        }
        // idempotent: same assignment again is a no-op
        let rep2 = eng.sync_assignment(&asn);
        assert_eq!(rep2.migrations, 0);
        assert_eq!(rep2.preserved, eng.n_fogs);
    }

    #[test]
    fn cardinalities_match_rebuilt_recount() {
        let mut eng = engine_fixture(100, 400, 3, 31);
        let mut plan = ChurnPlan::new(
            &[spec("del-vertex@rate=0.05")],
            31,
        );
        eng.churn_round(&mut plan);
        let rebuilt = eng.csr.to_graph();
        let cards = eng.cardinalities();
        let mut verts = vec![0usize; 3];
        let mut edges = vec![0usize; 3];
        for v in 0..rebuilt.num_vertices() {
            let j = eng.assignment[v] as usize;
            verts[j] += 1;
            edges[j] += rebuilt.degree(v);
        }
        for j in 0..3 {
            assert_eq!(cards[j], (verts[j], edges[j]), "fog {j}");
        }
    }
}
