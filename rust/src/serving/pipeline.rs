//! End-to-end serving pipelines — the paper's four comparison points plus
//! ablations, expressed as placement × codec × cluster combinations:
//!
//! * cloud          — single Cloud node, WAN collection, no compression
//! * single-fog     — the most powerful fog node alone
//! * multi-fog      — straw-man: BGP partitions, random fog mapping, no CO
//! * Fograph        — IEP (LBAP mapping) + communication optimizer
//! * ablations      — Fograph w/o IEP, Fograph w/o CO (Fig. 15)
//!
//! Latency composition (Eq. (7) + the BSP barrier structure of §III-E):
//!   total = max_j collection_j + Σ_k (max_j exec_{j,k} + δ_k) + unpack
//!
//! Execution goes through the engine's pluggable backend
//! (`runtime::backend::ExecBackend`): dense reference, sparse CSR
//! (`--engine csr`, no O(V²) buffers) or AOT PJRT. The request-level
//! loop on top of this pipeline (`traffic::sim`) can additionally run
//! measured per-batch execution (`--exec measured`).

use crate::compress::{Codec, DaqConfig, IntervalScheme, DEFAULT_BITS};
use crate::exec;
use crate::fog::{node::partition_footprint_bytes, Cluster};
use crate::graph::{DatasetSpec, Graph};
use crate::net::{self, NetKind};
use crate::partition::{baselines, MultilevelParams};
use crate::placement::{self, CostModel, MappingStrategy};
use crate::profile::PerfModel;
use crate::runtime::{reference, Engine, EngineError};

use super::collection;
use super::metrics::ServingReport;

/// Placement strategies across the evaluation.
#[derive(Clone, Debug)]
pub enum Placement {
    /// Everything on one node (cloud / single-fog).
    SingleNode(usize),
    /// §II-C motivation: random equal split.
    RandomSplit(u64),
    /// Straw-man multi-fog: min-cut partitions, stochastic mapping [39].
    MetisRandom(u64),
    /// METIS + greedy mapping (Fig. 8 baseline).
    MetisGreedy,
    /// Fograph's IEP (LBAP mapping).
    Iep,
}

#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub model: String,
    pub placement: Placement,
    pub codec: Codec,
    /// Number of source devices (contention; the paper's testbed has 8).
    pub devices: usize,
    /// Route collection over the WAN (cloud serving).
    pub wan: bool,
    pub keep_outputs: bool,
    /// Window start offset for temporal datasets (PeMS).
    pub window_start: usize,
    pub bgp_seed: u64,
}

impl ServeOpts {
    pub fn new(model: &str, placement: Placement, codec: Codec) -> Self {
        ServeOpts {
            model: model.to_string(),
            placement,
            codec,
            devices: 8,
            wan: false,
            keep_outputs: false,
            window_start: 1600,
            bgp_seed: 0xF06,
        }
    }

    /// Default DAQ codec for a graph.
    pub fn co_codec(g: &Graph) -> Codec {
        Codec::Daq(DaqConfig::from_degrees(
            &g.degrees(),
            IntervalScheme::EqualMass,
            DEFAULT_BITS,
        ))
    }
}

/// The four comparison systems of the evaluation, CLI spelling.
pub const MODES: [&str; 4] = ["cloud", "single-fog", "multi-fog",
                              "fograph"];

/// Cluster + options for one of the paper's comparison modes (shared by
/// `repro serve`, `repro loadtest` and the loadtest experiment).
pub fn mode_setup(mode: &str, model: &str, net: NetKind, g: &Graph)
                  -> Option<(Cluster, ServeOpts)> {
    match mode {
        "cloud" => Some((
            Cluster::cloud(net),
            ServeOpts {
                wan: true,
                ..ServeOpts::new(model, Placement::SingleNode(0),
                                 Codec::None)
            },
        )),
        "single-fog" => {
            let c = Cluster::testbed(net);
            let p = c.most_powerful();
            Some((c, ServeOpts::new(model, Placement::SingleNode(p),
                                    Codec::None)))
        }
        "multi-fog" => Some((
            Cluster::testbed(net),
            ServeOpts::new(model, Placement::MetisRandom(1), Codec::None),
        )),
        "fograph" => Some((
            Cluster::testbed(net),
            ServeOpts::new(model, Placement::Iep, ServeOpts::co_codec(g)),
        )),
        _ => None,
    }
}

/// Per-inference upload payload: static features, or the current window
/// slice for temporal datasets. Returns ([V, dims] row-major, dims).
pub fn query_payload(g: &Graph, spec: &DatasetSpec, window_start: usize)
                     -> (Vec<f32>, usize) {
    if spec.window <= 1 {
        return (g.features.clone(), g.feature_dim);
    }
    // features are [V, F, T]; take [V, F, window] at window_start and
    // flatten feature-major (matches python prep.pems_windows)
    let nv = g.num_vertices();
    let f = g.feature_dim;
    let t = g.duration;
    let w = spec.window;
    let start = window_start.min(t - w);
    let mut out = vec![0f32; nv * f * w];
    for v in 0..nv {
        for c in 0..f {
            for k in 0..w {
                out[v * f * w + c * w + k] =
                    g.features[v * f * t + c * t + start + k];
            }
        }
    }
    (out, f * w)
}

/// Compute the placement assignment for the options.
pub fn place(
    g: &Graph,
    cluster: &Cluster,
    opts: &ServeOpts,
    omegas: &[PerfModel],
    spec: &DatasetSpec,
) -> Vec<u32> {
    let n = cluster.len();
    match &opts.placement {
        Placement::SingleNode(idx) => vec![*idx as u32; g.num_vertices()],
        Placement::RandomSplit(seed) => {
            baselines::random_split(g, n, *seed)
        }
        Placement::MetisRandom(seed) => {
            let params = MultilevelParams {
                seed: opts.bgp_seed,
                ..Default::default()
            };
            let cost = default_cost_model(g, cluster, opts, spec);
            placement::plan(g, cluster, omegas, &cost,
                            MappingStrategy::Random(*seed), &params)
                .assignment
        }
        Placement::MetisGreedy => {
            let params = MultilevelParams {
                seed: opts.bgp_seed,
                ..Default::default()
            };
            let cost = default_cost_model(g, cluster, opts, spec);
            placement::plan(g, cluster, omegas, &cost,
                            MappingStrategy::Greedy, &params)
                .assignment
        }
        Placement::Iep => {
            let params = MultilevelParams {
                seed: opts.bgp_seed,
                ..Default::default()
            };
            let cost = default_cost_model(g, cluster, opts, spec);
            placement::plan(g, cluster, omegas, &cost,
                            MappingStrategy::Lbap, &params)
                .assignment
        }
    }
}

/// Planning-time φ estimate (wire bytes/vertex) for the cost model.
pub fn phi_estimate(g: &Graph, codec: &Codec, dims: usize) -> f64 {
    let raw = dims as f64 * 8.0;
    match codec {
        Codec::None => raw,
        Codec::Lz4Only => raw * 0.6,
        Codec::Uniform(bits) => {
            (dims as f64 * *bits as f64 / 8.0 + 9.0) * 0.7
        }
        Codec::Daq(cfg) => {
            let thm2 = cfg.theorem2_ratio(&g.degrees(), 64.0);
            raw * thm2 * 0.6 // LZ4 sparsity elimination on top of DAQ
        }
    }
}

pub fn default_cost_model(g: &Graph, cluster: &Cluster, opts: &ServeOpts,
                          spec: &DatasetSpec) -> CostModel {
    CostModel {
        phi_bytes: phi_estimate(g, &opts.codec, spec.input_dim()),
        k_layers: reference::model_layers(&opts.model),
        sync_row_bytes: (reference::HIDDEN * 4) as f64,
        devices_per_fog: opts.devices.div_ceil(cluster.len()).max(1),
        net: cluster.net,
    }
}

/// Run one end-to-end inference and account its latency.
pub fn serve(
    g: &Graph,
    spec: &DatasetSpec,
    cluster: &Cluster,
    opts: &ServeOpts,
    omegas: &[PerfModel],
    engine: &mut Engine,
) -> Result<ServingReport, EngineError> {
    let (payload, dims) = query_payload(g, spec, opts.window_start);
    let assignment = place(g, cluster, opts, omegas, spec);
    serve_with_assignment(g, spec, cluster, opts, &assignment, &payload,
                          dims, engine)
}

/// Like `serve` but with a precomputed placement (the adaptive scheduler
/// reuses this to run under migrated layouts).
#[allow(clippy::too_many_arguments)]
pub fn serve_with_assignment(
    g: &Graph,
    spec: &DatasetSpec,
    cluster: &Cluster,
    opts: &ServeOpts,
    assignment: &[u32],
    payload: &[f32],
    dims: usize,
    engine: &mut Engine,
) -> Result<ServingReport, EngineError> {
    let n_fogs = cluster.len();
    let mut report = ServingReport::default();

    // ---- OOM check (Fig. 18) ----------------------------------------------
    let mut fog_vertices = vec![0usize; n_fogs];
    for &a in assignment {
        fog_vertices[a as usize] += 1;
    }
    let k_layers = reference::model_layers(&opts.model);
    for (j, node) in cluster.nodes.iter().enumerate() {
        if fog_vertices[j] == 0 {
            continue;
        }
        // halo-augmented estimate: partitions see ~1.4x their vertices
        let v_est = (fog_vertices[j] as f64 * 1.4) as usize;
        let e_est = (g.num_edges() as f64 * fog_vertices[j] as f64
            / g.num_vertices() as f64
            * 1.3) as usize;
        let fp = partition_footprint_bytes(v_est, e_est, dims,
                                           reference::HIDDEN);
        if fp > node.serving_memory_bytes() {
            report.oom = true;
            report.per_fog_vertices = fog_vertices;
            return Ok(report);
        }
    }

    // ---- collection ---------------------------------------------------------
    let coll = collection::collect(g, payload, dims, assignment, cluster,
                                   &opts.codec, opts.devices, opts.wan);
    report.collection_s =
        coll.per_fog_s.iter().cloned().fold(0f64, f64::max);
    report.per_fog_collection_s = coll.per_fog_s.clone();
    report.unpack_s = coll.unpack_s;
    report.wire_bytes = coll.wire_bytes;
    report.raw_bytes = coll.raw_bytes;

    // ---- normalization for temporal models ---------------------------------
    let mut features = coll.features;
    if opts.model == "astgcn" {
        normalize_windows(&mut features, dims, spec, engine);
    }

    // ---- distributed BSP execution ------------------------------------------
    let bsp = exec::run_bsp(g, &features, dims, assignment, n_fogs,
                            &opts.model, spec.name, spec.classes, engine)?;
    // scale per-fog host times by node capability; barrier per layer
    let mut exec_total = 0f64;
    let mut per_fog_exec = vec![0f64; n_fogs];
    for layer_times in &bsp.layer_host_seconds {
        let mut layer_max = 0f64;
        for (j, &host) in layer_times.iter().enumerate() {
            let scaled = cluster.nodes[j].scale_time(host);
            per_fog_exec[j] += scaled;
            layer_max = layer_max.max(scaled);
        }
        exec_total += layer_max;
    }
    report.execution_s = exec_total;
    report.per_fog_exec_s = per_fog_exec;
    report.per_fog_vertices = bsp.fog_vertices.clone();

    // sync cost δ per layer boundary: transfers run pairwise-parallel
    // over the fog LAN, so the bottleneck is the max per-fog outgoing
    // payload (skip when single fog)
    if n_fogs > 1 {
        for &bytes in &bsp.sync_max_out {
            report.sync_s += net::transfer_time_s(
                bytes,
                cluster.net.interfog_mbps,
                cluster.net.interfog_rtt_s,
            );
        }
    }
    report.out_dim = bsp.out_dim;
    if opts.keep_outputs {
        let mut outputs = bsp.outputs;
        if opts.model == "astgcn" {
            // the model predicts NORMALIZED flow; de-normalize with the
            // training constants (channel 0 = flow) for downstream metrics
            let bundle = engine.weights("astgcn", spec.name, dims, 0);
            if bundle.contains("norm_mean") {
                let mean = bundle.get("norm_mean").unwrap().f32_data[0];
                let std = bundle.get("norm_std").unwrap().f32_data[0];
                for x in outputs.iter_mut() {
                    *x = *x * std + mean;
                }
            }
        }
        report.outputs = Some(outputs);
    }
    report.finalize();
    let _ = k_layers;
    Ok(report)
}

/// Standardize a PeMS window with the training normalization constants
/// (stored alongside the weights; falls back to batch statistics).
fn normalize_windows(features: &mut [f32], dims: usize,
                     spec: &DatasetSpec, engine: &mut Engine) {
    let w = spec.window;
    let f = spec.feature_dim;
    debug_assert_eq!(dims, f * w);
    let bundle = engine.weights("astgcn", spec.name, dims, 0);
    let (mean, std): (Vec<f32>, Vec<f32>) = if bundle.contains("norm_mean") {
        (
            bundle.get("norm_mean").unwrap().f32_data.clone(),
            bundle.get("norm_std").unwrap().f32_data.clone(),
        )
    } else {
        // batch stats fallback (untrained runs)
        let nv = features.len() / dims;
        let mut mean = vec![0f64; f];
        for v in 0..nv {
            for c in 0..f {
                for k in 0..w {
                    mean[c] += features[v * dims + c * w + k] as f64;
                }
            }
        }
        for m in mean.iter_mut() {
            *m /= (nv * w) as f64;
        }
        let mut var = vec![0f64; f];
        for v in 0..nv {
            for c in 0..f {
                for k in 0..w {
                    let d = features[v * dims + c * w + k] as f64 - mean[c];
                    var[c] += d * d;
                }
            }
        }
        (
            mean.iter().map(|&m| m as f32).collect(),
            var.iter()
                .map(|&v| ((v / 1f64.max(features.len() as f64 / f as f64))
                    .sqrt() as f32)
                    .max(1e-6))
                .collect(),
        )
    };
    let nv = features.len() / dims;
    for v in 0..nv {
        for c in 0..f {
            for k in 0..w {
                let x = &mut features[v * dims + c * w + k];
                *x = (*x - mean[c]) / std[c].max(1e-6);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;
    use crate::net::NetKind;
    use crate::runtime::EngineKind;

    fn tiny_spec() -> DatasetSpec {
        DatasetSpec {
            name: "tiny",
            vertices: 400,
            edges: 2000,
            feature_dim: 16,
            classes: 3,
            duration: 1,
            window: 1,
            seed: 1,
        }
    }

    fn tiny_graph() -> Graph {
        let (mut g, _) =
            crate::graph::generate::sbm(400, 2000, 8, 0.85, 3);
        let mut rng = crate::util::rng::Rng::new(5);
        g.feature_dim = 16;
        g.features = (0..400 * 16)
            .map(|_| if rng.bool(0.15) { 1.0 } else { 0.0 })
            .collect();
        g
    }

    fn engine() -> Engine {
        let dir = std::env::temp_dir().join("pipeline_test");
        std::fs::create_dir_all(&dir).unwrap();
        Engine::new(EngineKind::Reference, &dir).unwrap()
    }

    fn omegas(n: usize) -> Vec<PerfModel> {
        vec![PerfModel::uncalibrated(); n]
    }

    #[test]
    fn fograph_beats_cloud_and_strawman_fog() {
        let g = tiny_graph();
        let spec = tiny_spec();
        let mut eng = engine();

        let cloud_cluster = Cluster::cloud(NetKind::Cell4G);
        let cloud = serve(
            &g, &spec, &cloud_cluster,
            &ServeOpts {
                wan: true,
                ..ServeOpts::new("gcn", Placement::SingleNode(0),
                                 Codec::None)
            },
            &omegas(1), &mut eng,
        ).unwrap();

        let fog_cluster = Cluster::testbed(NetKind::Cell4G);
        let strawman = serve(
            &g, &spec, &fog_cluster,
            &ServeOpts::new("gcn", Placement::MetisRandom(7), Codec::None),
            &omegas(6), &mut eng,
        ).unwrap();

        let fograph = serve(
            &g, &spec, &fog_cluster,
            &ServeOpts::new("gcn", Placement::Iep,
                            ServeOpts::co_codec(&g)),
            &omegas(6), &mut eng,
        ).unwrap();

        assert!(
            fograph.total_s < strawman.total_s,
            "fograph {:.4} !< strawman {:.4}",
            fograph.total_s, strawman.total_s
        );
        assert!(
            fograph.total_s < cloud.total_s,
            "fograph {:.4} !< cloud {:.4}",
            fograph.total_s, cloud.total_s
        );
        assert!(fograph.throughput > cloud.throughput);
        // cloud is dominated by communication (>90% per §II-C)
        assert!(cloud.comm_fraction() > 0.9,
                "cloud comm fraction {}", cloud.comm_fraction());
    }

    #[test]
    fn outputs_identical_across_placements_without_codec() {
        let g = tiny_graph();
        let spec = tiny_spec();
        let mut eng = engine();
        let cluster = Cluster::testbed(NetKind::Wifi);
        let mut opts = ServeOpts::new("gcn", Placement::SingleNode(0),
                                      Codec::None);
        opts.keep_outputs = true;
        let single = serve(&g, &spec, &Cluster::cloud(NetKind::Wifi),
                           &opts, &omegas(1), &mut eng).unwrap();
        let mut opts2 = ServeOpts::new("gcn", Placement::Iep, Codec::None);
        opts2.keep_outputs = true;
        let multi = serve(&g, &spec, &cluster, &opts2, &omegas(6),
                          &mut eng).unwrap();
        let a = single.outputs.unwrap();
        let b = multi.outputs.unwrap();
        let err = a.iter().zip(&b).map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(err < 2e-4, "placement changed outputs by {err}");
    }

    #[test]
    fn pems_window_payload_shape() {
        let g = datasets::generate("pems").unwrap();
        let spec = datasets::PEMS;
        let (payload, dims) = query_payload(&g, &spec, 100);
        assert_eq!(dims, 36);
        assert_eq!(payload.len(), 307 * 36);
        // window slice matches the raw series
        let t = g.duration;
        assert_eq!(payload[0], g.features[100]); // v0, c0, k0
        assert_eq!(payload[36 + 12], g.features[3 * t + t + 100]);
        // ^ v1 (offset 36), channel 1 (offset 12 in window), k0
    }

    #[test]
    fn oom_reported_for_gpu_single_fog_on_big_graph() {
        // synthetic large spec: don't build the real rmat100k in tests
        let (mut g, _) = crate::graph::generate::sbm(2000, 10_000, 4, 0.9, 2);
        g.feature_dim = 32;
        g.features = vec![0.0; 2000 * 32];
        let spec = DatasetSpec {
            name: "tiny100k",
            vertices: 2000,
            edges: 10_000,
            feature_dim: 32,
            classes: 8,
            duration: 1,
            window: 1,
            seed: 2,
        };
        let mut eng = engine();
        let mut cluster = Cluster::uniform_b(1, NetKind::Wifi).with_gpus();
        // shrink GPU memory so the test graph overflows it
        cluster.nodes[0].gpu = Some(crate::fog::GpuSpec {
            multiplier: 0.22,
            memory_bytes: 1 << 20,
        });
        let r = serve(&g, &spec, &cluster,
                      &ServeOpts::new("gcn", Placement::SingleNode(0),
                                      Codec::None),
                      &omegas(1), &mut eng).unwrap();
        assert!(r.oom);
    }
}
