//! Serving metrics: the stage-wise latency breakdown of Fig. 3/15 and the
//! pipelined-throughput model of Fig. 12/13(d).

/// One inference's end-to-end accounting (all seconds, simulated clock).
#[derive(Clone, Debug, Default)]
pub struct ServingReport {
    /// max over fogs of device→fog (or →cloud) upload, incl. packing.
    pub collection_s: f64,
    /// Σ over layers of (max-fog layer time), without sync.
    pub execution_s: f64,
    /// Σ over layers of the synchronization cost δ.
    pub sync_s: f64,
    /// Unpacking on the fog side (pipelined share).
    pub unpack_s: f64,
    pub total_s: f64,
    /// Steady-state pipelined inferences/second.
    pub throughput: f64,
    /// Bytes on the wire for one inference's data collection.
    pub wire_bytes: usize,
    /// Raw (uncompressed f64) payload bytes.
    pub raw_bytes: usize,
    /// Per-fog detail (index = fog id).
    pub per_fog_vertices: Vec<usize>,
    pub per_fog_collection_s: Vec<f64>,
    pub per_fog_exec_s: Vec<f64>,
    /// Whether any fog exceeded its serving memory (Fig. 18 OOM).
    pub oom: bool,
    /// Model outputs [V, out_dim] (when requested).
    pub outputs: Option<Vec<f32>>,
    pub out_dim: usize,
}

impl ServingReport {
    /// The two pipeline stages overlap across successive inferences:
    /// collection of query i+1 proceeds while query i executes.
    pub fn compute_throughput(&mut self) {
        let exec_stage = self.execution_s + self.sync_s + self.unpack_s;
        let bottleneck = self.collection_s.max(exec_stage);
        self.throughput =
            if bottleneck > 0.0 { 1.0 / bottleneck } else { 0.0 };
    }

    pub fn finalize(&mut self) {
        self.total_s = self.collection_s + self.execution_s + self.sync_s
            + self.unpack_s;
        self.compute_throughput();
    }

    /// Communication share of the total (Fig. 3-right / Fig. 15-right).
    pub fn comm_fraction(&self) -> f64 {
        if self.total_s == 0.0 {
            return 0.0;
        }
        (self.collection_s + self.sync_s + self.unpack_s) / self.total_s
    }
}

/// Aggregate repeat runs into one report (outputs from the last run).
/// Components use the MEDIAN: single-core wall-clock measurement is
/// outlier-prone and the paper reports typical-case latency.
pub fn average(reports: Vec<ServingReport>) -> ServingReport {
    assert!(!reports.is_empty());
    let med = |xs: Vec<f64>| crate::util::stats::percentile(&xs, 50.0);
    let mut acc = reports.last().unwrap().clone();
    acc.collection_s =
        med(reports.iter().map(|r| r.collection_s).collect());
    acc.execution_s =
        med(reports.iter().map(|r| r.execution_s).collect());
    acc.sync_s = med(reports.iter().map(|r| r.sync_s).collect());
    acc.unpack_s = med(reports.iter().map(|r| r.unpack_s).collect());
    acc.finalize();
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finalize_sums_stages_and_pipelines_throughput() {
        let mut r = ServingReport {
            collection_s: 0.6,
            execution_s: 0.3,
            sync_s: 0.05,
            unpack_s: 0.05,
            ..Default::default()
        };
        r.finalize();
        assert!((r.total_s - 1.0).abs() < 1e-12);
        // collection (0.6) dominates the exec stage (0.4)
        assert!((r.throughput - 1.0 / 0.6).abs() < 1e-9);
        assert!((r.comm_fraction() - 0.7).abs() < 1e-9);
    }

    #[test]
    fn average_is_elementwise_mean() {
        let mk = |c: f64| {
            let mut r = ServingReport {
                collection_s: c,
                execution_s: 0.2,
                ..Default::default()
            };
            r.finalize();
            r
        };
        let avg = average(vec![mk(0.4), mk(0.8)]);
        assert!((avg.collection_s - 0.6).abs() < 1e-12);
    }
}
