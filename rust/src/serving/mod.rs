//! Serving layer: data-collection simulation, end-to-end pipelines
//! (cloud / single-fog / straw-man multi-fog / Fograph / ablations),
//! latency+throughput metrics, inference-quality evaluation, and the
//! scale tier's spill-aware feature store.

pub mod accuracy;
pub mod collection;
pub mod metrics;
pub mod pipeline;
pub mod store;

pub use collection::CollectionIndex;
pub use metrics::ServingReport;
pub use pipeline::{mode_setup, serve, serve_with_assignment, Placement,
                   ServeOpts, MODES};
pub use store::{FeatureStore, StoreStats};
