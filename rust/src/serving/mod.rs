//! Serving layer: data-collection simulation, end-to-end pipelines
//! (cloud / single-fog / straw-man multi-fog / Fograph / ablations),
//! latency+throughput metrics, and inference-quality evaluation.

pub mod accuracy;
pub mod collection;
pub mod metrics;
pub mod pipeline;

pub use metrics::ServingReport;
pub use pipeline::{mode_setup, serve, serve_with_assignment, Placement,
                   ServeOpts, MODES};
