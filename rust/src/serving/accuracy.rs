//! Inference-quality evaluation (Table IV accuracy, Table V forecasting
//! errors): runs the serving output against labels / future ground truth,
//! under whichever codec the pipeline applied to the uploaded features.

use crate::graph::{DatasetSpec, Graph};

/// Deterministic train/test split — MUST match python prep.train_test_split
/// (test accuracy is computed on the same held-out vertices the trainer
/// reported on).
pub fn test_indices(v: usize, train_frac: f64) -> Vec<usize> {
    (0..v)
        .filter(|&i| {
            let h = (i as u64).wrapping_mul(2654435761) % 4294967296;
            (h % 1000) as f64 >= train_frac * 1000.0
        })
        .collect()
}

/// Classification accuracy of logits [V, C] on the held-out split.
pub fn accuracy(outputs: &[f32], out_dim: usize, labels: &[i32]) -> f64 {
    let test = test_indices(labels.len(), 0.7);
    let mut correct = 0usize;
    for &v in &test {
        let row = &outputs[v * out_dim..(v + 1) * out_dim];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        if pred == labels[v] {
            correct += 1;
        }
    }
    correct as f64 / test.len().max(1) as f64
}

/// Forecasting errors at a horizon index (0-based step into the predicted
/// hour): MAE, RMSE, MAPE — Table V's metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ForecastErrors {
    pub mae: f64,
    pub rmse: f64,
    pub mape: f64,
}

/// `outputs` [V, T_out] de-normalized flow predictions; ground truth from
/// the stored series at `window_start + window`.
pub fn forecast_errors(
    g: &Graph,
    spec: &DatasetSpec,
    outputs: &[f32],
    t_out: usize,
    window_start: usize,
    horizon_steps: usize,
) -> ForecastErrors {
    let nv = g.num_vertices();
    let t = g.duration;
    let base = window_start + spec.window;
    assert!(base + t_out <= t, "window beyond series end");
    assert!(horizon_steps >= 1 && horizon_steps <= t_out);
    let mut abs = 0f64;
    let mut sq = 0f64;
    let mut ape = 0f64;
    let mut count = 0usize;
    for v in 0..nv {
        // flow channel is 0: features[v*3T .. v*3T+T]
        for k in 0..horizon_steps {
            let truth = g.features[v * 3 * t + base + k] as f64;
            let pred = outputs[v * t_out + k] as f64;
            let d = pred - truth;
            abs += d.abs();
            sq += d * d;
            if truth.abs() > 1.0 {
                ape += (d / truth).abs();
            }
            count += 1;
        }
    }
    ForecastErrors {
        mae: abs / count as f64,
        rmse: (sq / count as f64).sqrt(),
        mape: ape / count as f64 * 100.0,
    }
}

/// Average forecast errors over several query windows.
pub fn average_errors(errs: &[ForecastErrors]) -> ForecastErrors {
    let n = errs.len().max(1) as f64;
    ForecastErrors {
        mae: errs.iter().map(|e| e.mae).sum::<f64>() / n,
        rmse: errs.iter().map(|e| e.rmse).sum::<f64>() / n,
        mape: errs.iter().map(|e| e.mape).sum::<f64>() / n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets;

    #[test]
    fn split_matches_python_hash() {
        // python: (idx * 2654435761 % 2**32) % 1000 < 700 -> train
        let test = test_indices(100, 0.7);
        for &i in &test {
            let h = (i as u64).wrapping_mul(2654435761) % 4294967296;
            assert!((h % 1000) >= 700);
        }
        // roughly 30%
        assert!(test.len() > 15 && test.len() < 45, "{}", test.len());
    }

    #[test]
    fn perfect_predictions_give_perfect_accuracy() {
        let labels = vec![0, 1, 1, 0, 1, 0, 0, 1, 1, 0];
        let mut outputs = vec![0f32; 20];
        for (i, &l) in labels.iter().enumerate() {
            outputs[i * 2 + l as usize] = 5.0;
        }
        assert_eq!(accuracy(&outputs, 2, &labels), 1.0);
        // flip all predictions -> 0
        let mut flipped = vec![0f32; 20];
        for (i, &l) in labels.iter().enumerate() {
            flipped[i * 2 + (1 - l) as usize] = 5.0;
        }
        assert_eq!(accuracy(&flipped, 2, &labels), 0.0);
    }

    #[test]
    fn forecast_errors_zero_for_oracle() {
        let g = datasets::generate("pems").unwrap();
        let spec = datasets::PEMS;
        let start = 500;
        let t = g.duration;
        let t_out = 12;
        // oracle: copy the truth into predictions
        let mut outputs = vec![0f32; g.num_vertices() * t_out];
        for v in 0..g.num_vertices() {
            for k in 0..t_out {
                outputs[v * t_out + k] =
                    g.features[v * 3 * t + start + spec.window + k];
            }
        }
        let e = forecast_errors(&g, &spec, &outputs, t_out, start, 6);
        assert!(e.mae < 1e-6 && e.rmse < 1e-6 && e.mape < 1e-6);
        // constant predictor has substantial error
        let flat = vec![250f32; g.num_vertices() * t_out];
        let ef = forecast_errors(&g, &spec, &flat, t_out, start, 6);
        assert!(ef.mae > 10.0);
    }
}
