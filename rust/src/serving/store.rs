//! Spill-aware per-partition feature storage for the scale tier
//! (ROADMAP item 3): a fog's feature blocks live in a [`FeatureStore`]
//! with a bounded resident budget (`--fog-mem-mb`). Hot blocks stay
//! resident as flat f32 rows; cold blocks are spilled through the
//! existing `compress/` pipeline (quantize + shuffle + LZ4) and
//! transparently rehydrated on access.
//!
//! With the quantizer off (`Codec::Lz4Only`, the default spill codec)
//! the round-trip is BIT-exact: 64-bit "quantization" ships the f64
//! widening of each f32, which narrows back losslessly. Lossy codecs
//! (`Daq`, `Uniform`) trade fidelity for a smaller spill footprint and
//! are opt-in. `Codec::None` cannot back a spill (its unpack returns
//! no rows) and is rejected for bounded stores.
//!
//! With no budget (`budget = None`) the store is a pure passthrough —
//! nothing is ever packed, `get` returns exactly the inserted rows —
//! so small-graph runs take the exact pre-spill code path.

use crate::compress::{self, Codec, Packed};

/// Spill/rehydrate counters and resident-memory accounting. All sizes
/// are logical heap bytes of the stored rows (deterministic, unlike
/// process RSS).
#[derive(Clone, Debug, Default)]
pub struct StoreStats {
    /// Blocks packed out of residency.
    pub spills: usize,
    /// Blocks unpacked back on access.
    pub rehydrates: usize,
    /// Resident row bytes right now.
    pub resident_bytes: usize,
    /// High-water mark of `resident_bytes`.
    pub peak_resident_bytes: usize,
    /// Cumulative packed bytes written by spills.
    pub spilled_wire_bytes: usize,
}

enum Slot {
    Vacant,
    Resident { rows: Vec<f32>, degrees: Vec<u64> },
    Spilled { packed: Packed, degrees: Vec<u64>, n_rows: usize },
}

/// Bounded-residency feature block store; see the module docs.
pub struct FeatureStore {
    dims: usize,
    codec: Codec,
    budget_bytes: Option<usize>,
    slots: Vec<Slot>,
    /// Block ids in recency order, least-recently-touched first.
    lru: Vec<usize>,
    stats: StoreStats,
}

impl FeatureStore {
    /// `budget_mb` is the `--fog-mem-mb` knob: `None` = unbounded
    /// passthrough.
    pub fn new(n_blocks: usize, dims: usize, budget_mb: Option<usize>,
               codec: Codec) -> FeatureStore {
        FeatureStore::with_budget_bytes(
            n_blocks,
            dims,
            budget_mb.map(|mb| mb * (1 << 20)),
            codec,
        )
    }

    /// Byte-granular constructor (tests and callers that size budgets
    /// from data rather than a CLI flag).
    pub fn with_budget_bytes(n_blocks: usize, dims: usize,
                             budget_bytes: Option<usize>,
                             codec: Codec) -> FeatureStore {
        assert!(dims > 0, "feature dims must be positive");
        assert!(
            budget_bytes.is_none() || codec != Codec::None,
            "a bounded store needs a spill codec that round-trips \
             rows; Codec::None does not"
        );
        FeatureStore {
            dims,
            codec,
            budget_bytes,
            slots: (0..n_blocks).map(|_| Slot::Vacant).collect(),
            lru: Vec::with_capacity(n_blocks),
            stats: StoreStats::default(),
        }
    }

    pub fn stats(&self) -> &StoreStats {
        &self.stats
    }

    pub fn is_resident(&self, block: usize) -> bool {
        matches!(self.slots[block], Slot::Resident { .. })
    }

    /// Insert (or replace) a block: `rows` is row-major `[n, dims]`,
    /// `degrees` the rows' full-graph degrees (the degree-aware spill
    /// codecs key bitwidths off them; `Lz4Only` ignores them). The
    /// block becomes the hottest entry; colder blocks may spill to
    /// honor the budget.
    pub fn insert(&mut self, block: usize, rows: Vec<f32>,
                  degrees: Vec<u64>) {
        assert_eq!(rows.len(), degrees.len() * self.dims);
        if let Slot::Resident { rows: old, .. } = &self.slots[block] {
            self.stats.resident_bytes -= old.len() * 4;
        }
        self.stats.resident_bytes += rows.len() * 4;
        self.stats.peak_resident_bytes = self
            .stats
            .peak_resident_bytes
            .max(self.stats.resident_bytes);
        self.slots[block] = Slot::Resident { rows, degrees };
        self.touch(block);
        self.enforce(block);
    }

    /// Access a block's rows, rehydrating a spilled block in place.
    /// The touched block becomes the hottest entry and is never the
    /// spill victim of its own access — even when it alone exceeds
    /// the budget (serving needs the rows resident), in which case
    /// every OTHER block spills and the budget is overshot by exactly
    /// this block.
    pub fn get(&mut self, block: usize) -> &[f32] {
        if matches!(self.slots[block], Slot::Spilled { .. }) {
            self.rehydrate(block);
        }
        self.touch(block);
        self.enforce(block);
        match &self.slots[block] {
            Slot::Resident { rows, .. } => rows,
            Slot::Vacant => panic!("get() on never-inserted block"),
            Slot::Spilled { .. } => {
                unreachable!("block resident after rehydrate")
            }
        }
    }

    fn touch(&mut self, block: usize) {
        self.lru.retain(|&b| b != block);
        self.lru.push(block);
    }

    /// Spill least-recently-touched resident blocks (never `protect`)
    /// until the budget holds or nothing else can move.
    fn enforce(&mut self, protect: usize) {
        let Some(budget) = self.budget_bytes else { return };
        while self.stats.resident_bytes > budget {
            let victim = self.lru.iter().copied().find(|&b| {
                b != protect
                    && matches!(self.slots[b], Slot::Resident { .. })
            });
            match victim {
                Some(v) => self.spill(v),
                None => break,
            }
        }
    }

    fn spill(&mut self, block: usize) {
        let slot =
            std::mem::replace(&mut self.slots[block], Slot::Vacant);
        let Slot::Resident { rows, degrees } = slot else {
            unreachable!("spill victim must be resident")
        };
        let refs: Vec<&[f32]> = rows.chunks(self.dims).collect();
        let packed = compress::pack(&refs, &degrees, &self.codec);
        self.stats.spills += 1;
        self.stats.spilled_wire_bytes += packed.wire_bytes;
        self.stats.resident_bytes -= rows.len() * 4;
        let n_rows = degrees.len();
        self.slots[block] = Slot::Spilled { packed, degrees, n_rows };
    }

    fn rehydrate(&mut self, block: usize) {
        let slot =
            std::mem::replace(&mut self.slots[block], Slot::Vacant);
        let Slot::Spilled { packed, degrees, n_rows } = slot else {
            unreachable!("rehydrate target must be spilled")
        };
        let mut rows_out: Vec<Vec<f32>> = Vec::new();
        compress::unpack(&packed, &mut rows_out)
            .expect("spill blob must rehydrate");
        assert_eq!(rows_out.len(), n_rows, "rehydrated row count");
        let mut rows = Vec::with_capacity(n_rows * self.dims);
        for r in &rows_out {
            assert_eq!(r.len(), self.dims);
            rows.extend_from_slice(r);
        }
        self.stats.rehydrates += 1;
        self.stats.resident_bytes += rows.len() * 4;
        self.stats.peak_resident_bytes = self
            .stats
            .peak_resident_bytes
            .max(self.stats.resident_bytes);
        self.slots[block] = Slot::Resident { rows, degrees };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn block(n: usize, dims: usize, seed: u64) -> (Vec<f32>, Vec<u64>) {
        let mut rng = Rng::new(seed);
        let rows: Vec<f32> =
            (0..n * dims).map(|_| rng.normal_f32(0.0, 3.0)).collect();
        let degrees: Vec<u64> =
            (0..n).map(|i| 1 + (i as u64 % 17)).collect();
        (rows, degrees)
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn unbounded_store_is_pure_passthrough() {
        let mut st = FeatureStore::with_budget_bytes(
            3, 8, None, Codec::Lz4Only);
        let blocks: Vec<_> =
            (0..3).map(|i| block(20 + i, 8, i as u64)).collect();
        for (i, (rows, degs)) in blocks.iter().enumerate() {
            st.insert(i, rows.clone(), degs.clone());
        }
        for round in 0..3 {
            for i in 0..3 {
                assert!(st.is_resident(i), "round {round} block {i}");
                assert_eq!(bits(st.get(i)), bits(&blocks[i].0));
            }
        }
        assert_eq!(st.stats().spills, 0);
        assert_eq!(st.stats().rehydrates, 0);
        let expect: usize =
            blocks.iter().map(|(r, _)| r.len() * 4).sum();
        assert_eq!(st.stats().resident_bytes, expect);
    }

    #[test]
    fn spill_roundtrip_is_bit_exact_with_quantizer_off() {
        // 4 blocks of 4 KiB under a 10 KiB budget: at least one must
        // spill, and every access must still be bit-identical
        let dims = 32;
        let n = 32; // 32 rows * 32 dims * 4 B = 4 KiB
        let mut st = FeatureStore::with_budget_bytes(
            4, dims, Some(10 * 1024), Codec::Lz4Only);
        let blocks: Vec<_> =
            (0..4).map(|i| block(n, dims, 100 + i as u64)).collect();
        for (i, (rows, degs)) in blocks.iter().enumerate() {
            st.insert(i, rows.clone(), degs.clone());
        }
        assert!(st.stats().spills > 0, "budget never forced a spill");
        assert!(st.stats().resident_bytes <= 10 * 1024);
        let mut rehydrated = 0;
        for round in 0..3 {
            for i in 0..4 {
                let was_spilled = !st.is_resident(i);
                rehydrated += usize::from(was_spilled);
                assert_eq!(
                    bits(st.get(i)),
                    bits(&blocks[i].0),
                    "round {round} block {i} (spilled={was_spilled})"
                );
            }
        }
        assert!(rehydrated > 0);
        assert_eq!(st.stats().rehydrates, rehydrated);
        assert!(st.stats().spilled_wire_bytes > 0);
    }

    #[test]
    fn lru_keeps_the_hot_block_resident() {
        let dims = 16;
        let n = 16; // 1 KiB per block
        let mut st = FeatureStore::with_budget_bytes(
            3, dims, Some(2 * 1024), Codec::Lz4Only);
        for i in 0..3 {
            let (rows, degs) = block(n, dims, i as u64);
            st.insert(i, rows, degs);
        }
        // 3 KiB inserted under 2 KiB: the coldest (block 0) spilled
        assert!(!st.is_resident(0));
        assert!(st.is_resident(2));
        // touching 0 rehydrates it and evicts the now-coldest (1)
        let _ = st.get(0);
        assert!(st.is_resident(0));
        assert!(!st.is_resident(1));
    }

    #[test]
    fn oversized_hot_block_stays_resident() {
        let dims = 16;
        let mut st = FeatureStore::with_budget_bytes(
            2, dims, Some(512), Codec::Lz4Only);
        let (big, degs) = block(64, dims, 9); // 4 KiB > 512 B budget
        st.insert(0, big.clone(), degs);
        let (small, sdegs) = block(4, dims, 10);
        st.insert(1, small, sdegs);
        // serving needs the accessed rows resident even over budget
        assert_eq!(bits(st.get(0)), bits(&big));
        assert!(st.is_resident(0));
        assert!(!st.is_resident(1), "everything else spilled");
    }

    #[test]
    fn zero_and_one_row_blocks_survive_spill() {
        let dims = 8;
        let mut st = FeatureStore::with_budget_bytes(
            3, dims, Some(256), Codec::Lz4Only);
        st.insert(0, Vec::new(), Vec::new());
        let (one, odegs) = block(1, dims, 5);
        st.insert(1, one.clone(), odegs);
        let (filler, fdegs) = block(32, dims, 6); // 1 KiB: evicts 0+1
        st.insert(2, filler, fdegs);
        assert!(st.get(0).is_empty());
        assert_eq!(bits(st.get(1)), bits(&one));
    }

    #[test]
    fn lossy_spill_codec_is_close_but_not_exact() {
        let dims = 16;
        let mut st = FeatureStore::with_budget_bytes(
            2, dims, Some(1024), Codec::Uniform(8));
        let mut rng = Rng::new(3);
        let rows: Vec<f32> =
            (0..32 * dims).map(|_| rng.f64() as f32).collect();
        let degs: Vec<u64> = vec![4; 32];
        st.insert(0, rows.clone(), degs); // 2 KiB > 1 KiB but hot
        let (other, odegs) = block(16, dims, 4);
        st.insert(1, other, odegs); // block 0 spills (lossily)
        assert!(!st.is_resident(0));
        let back = st.get(0).to_vec();
        let max_err = rows
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err > 0.0, "uniform-8 cannot be exact here");
        assert!(max_err < 0.05, "max err {max_err}");
    }

    #[test]
    #[should_panic(expected = "spill codec")]
    fn bounded_store_rejects_codec_none() {
        let _ = FeatureStore::with_budget_bytes(
            1, 4, Some(1024), Codec::None);
    }
}
