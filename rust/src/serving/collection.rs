//! Data-collection stage (paper Fig. 5 ❸ + §III-D deployment): devices
//! pack their local readings (quantize + shuffle + LZ4), upload over the
//! access network, and fogs unpack on a side thread pipelined with
//! inference.
//!
//! The packing/unpacking COMPUTE times are real (measured on this host and
//! scaled to device/fog capability); the TRANSFER times are analytic from
//! the calibrated network model.

use crate::compress::{self, Codec};
use crate::fog::Cluster;
use crate::graph::Graph;
use crate::net;
use crate::obs::clock::Stopwatch;

/// End devices (Raspberry-Pi class) are markedly slower than this host at
/// the packing arithmetic.
pub const DEVICE_COMPUTE_MULT: f64 = 6.0;
/// Unpacking runs on a separate fog thread, pipelined with inference
/// (§III-D "Deployment of CO"); only this share lands on the critical path.
pub const UNPACK_PIPELINE_SHARE: f64 = 0.25;

/// Placement-static collection state: per-fog vertex lists and degree
/// rows, built ONCE per layout instead of re-sweeping all V vertices
/// (plus a fresh `g.degrees()` allocation) on every collection call.
/// The traffic fabric rebuilds it only when a diffusion / replan /
/// evacuation actually moves the assignment; the scale tier reuses it
/// across every access round.
#[derive(Clone, Debug)]
pub struct CollectionIndex {
    n_fogs: usize,
    /// Fog → owned vertex ids, ascending (global order within a fog).
    pub by_fog: Vec<Vec<u32>>,
    /// Fog → owned vertices' FULL-graph degrees, aligned with `by_fog`.
    pub degrees: Vec<Vec<u64>>,
    /// Fogs that actually receive data (AP-contention input).
    pub active_fogs: usize,
}

impl CollectionIndex {
    /// One O(V) sweep over the assignment.
    pub fn build(g: &Graph, assignment: &[u32], n_fogs: usize)
                 -> CollectionIndex {
        let nv = g.num_vertices();
        assert_eq!(assignment.len(), nv);
        let mut by_fog: Vec<Vec<u32>> = vec![Vec::new(); n_fogs];
        for v in 0..nv {
            by_fog[assignment[v] as usize].push(v as u32);
        }
        let degrees: Vec<Vec<u64>> = by_fog
            .iter()
            .map(|verts| {
                verts
                    .iter()
                    .map(|&v| g.degree(v as usize) as u64)
                    .collect()
            })
            .collect();
        let active_fogs =
            by_fog.iter().filter(|v| !v.is_empty()).count();
        CollectionIndex { n_fogs, by_fog, degrees, active_fogs }
    }

    /// Placeholder before the first placement exists (no fog owns
    /// anything; `build` replaces it as soon as a layout lands).
    pub fn empty(n_fogs: usize) -> CollectionIndex {
        CollectionIndex {
            n_fogs,
            by_fog: vec![Vec::new(); n_fogs],
            degrees: vec![Vec::new(); n_fogs],
            active_fogs: 0,
        }
    }

    /// Assemble from precomputed per-fog vertex/degree rows — the
    /// incremental topology engine's entry point, which maintains both
    /// under churn instead of re-sweeping a static graph. Rows must be
    /// ascending per fog and aligned, exactly as `build` produces.
    pub fn from_parts(by_fog: Vec<Vec<u32>>, degrees: Vec<Vec<u64>>)
                      -> CollectionIndex {
        assert_eq!(by_fog.len(), degrees.len());
        debug_assert!(by_fog
            .iter()
            .zip(&degrees)
            .all(|(v, d)| v.len() == d.len()));
        let n_fogs = by_fog.len();
        let active_fogs =
            by_fog.iter().filter(|v| !v.is_empty()).count();
        CollectionIndex { n_fogs, by_fog, degrees, active_fogs }
    }
}

#[derive(Clone, Debug)]
pub struct CollectionResult {
    /// Per-fog collection latency (transfer + device-side packing).
    pub per_fog_s: Vec<f64>,
    /// Analytic transfer-only share of `per_fog_s` (no measured packing
    /// compute): a pure function of the inputs. The steady-state loop in
    /// `traffic::sim` uses this — packing of window k+1 overlaps the
    /// upload of window k, mirroring the unpack-side pipelining.
    pub per_fog_transfer_s: Vec<f64>,
    /// Pipelined unpack cost on the critical path (max over fogs).
    pub unpack_s: f64,
    pub wire_bytes: usize,
    pub raw_bytes: usize,
    /// Dequantized features [V, F·W] in GLOBAL vertex order (what the
    /// fogs' runtimes see after unpacking).
    pub features: Vec<f32>,
}

/// Simulate the collection stage for a placement.
///
/// * `window_features` — [V, D] per-vertex upload payload for this query
///   (for PeMS this is the current 12-step window, already flattened).
/// * `assignment` — vertex → fog id (all-zeros + n_fogs=1 for cloud).
/// * `devices` — number of source devices (APs contention input).
/// * `wan` — route uploads over the WAN (cloud serving).
pub fn collect(
    g: &Graph,
    window_features: &[f32],
    dims: usize,
    assignment: &[u32],
    cluster: &Cluster,
    codec: &Codec,
    devices: usize,
    wan: bool,
) -> CollectionResult {
    let idx = CollectionIndex::build(g, assignment, cluster.len());
    collect_indexed(g, &idx, window_features, dims, cluster, codec,
                    devices, wan)
}

/// `collect` against a prebuilt [`CollectionIndex`] — the per-request
/// hot path. Identical arithmetic and iteration order to building the
/// index inline, so results are bit-identical to `collect`.
pub fn collect_indexed(
    g: &Graph,
    idx: &CollectionIndex,
    window_features: &[f32],
    dims: usize,
    cluster: &Cluster,
    codec: &Codec,
    devices: usize,
    wan: bool,
) -> CollectionResult {
    // Derive the vertex universe from the payload, not the graph:
    // under churn the fabric's payload grows past the build-time
    // `g.num_vertices()` as vertices join. Churn-free callers always
    // pass exactly `g.num_vertices() * dims`, so nothing changes.
    assert_eq!(window_features.len() % dims.max(1), 0);
    let nv = window_features.len() / dims.max(1);
    assert!(nv >= g.num_vertices(), "payload smaller than graph");
    let n_fogs = cluster.len();
    assert_eq!(idx.n_fogs, n_fogs, "index built for another cluster");

    let mut per_fog_s = vec![0f64; n_fogs];
    let mut per_fog_transfer_s = vec![0f64; n_fogs];
    let mut unpack_s = 0f64;
    let mut wire_total = 0usize;
    let mut raw_total = 0usize;
    let mut features = vec![0f32; nv * dims];

    // contention spreads over the fogs that actually receive data (a
    // single-fog placement concentrates every device on one AP)
    let devices_per_fog =
        devices.div_ceil(idx.active_fogs.max(1)).max(1);

    for (j, verts) in idx.by_fog.iter().enumerate() {
        if verts.is_empty() {
            continue;
        }
        let rows: Vec<&[f32]> = verts
            .iter()
            .map(|&v| {
                &window_features[v as usize * dims..(v as usize + 1) * dims]
            })
            .collect();
        let degs = &idx.degrees[j];
        let t_pack = Stopwatch::start();
        let packed = compress::pack(&rows, degs, codec);
        let pack_host = t_pack.elapsed_s();
        // devices pack their shards in parallel; per-device share
        let pack_device_s = pack_host * DEVICE_COMPUTE_MULT
            / devices_per_fog as f64;

        let t_unpack = Stopwatch::start();
        let mut rows_out: Vec<Vec<f32>> = Vec::new();
        compress::unpack(&packed, &mut rows_out).expect("unpack");
        let unpack_host = t_unpack.elapsed_s();
        let fog_mult = cluster.nodes[j].effective_multiplier();
        unpack_s = unpack_s
            .max(unpack_host * fog_mult * UNPACK_PIPELINE_SHARE);

        // write dequantized rows back in global order
        if rows_out.is_empty() {
            for &v in verts {
                let src = &window_features
                    [v as usize * dims..(v as usize + 1) * dims];
                features[v as usize * dims..(v as usize + 1) * dims]
                    .copy_from_slice(src);
            }
        } else {
            for (&v, row) in verts.iter().zip(&rows_out) {
                features[v as usize * dims..(v as usize + 1) * dims]
                    .copy_from_slice(row);
            }
        }

        let bw = if wan {
            net::cloud_uplink_mbps(&cluster.net, devices)
        } else {
            net::fog_uplink_mbps(&cluster.net, devices_per_fog)
                * cluster.nodes[j].node_type.bandwidth_share()
        };
        let rtt = if wan {
            cluster.net.wan_rtt_s
        } else {
            cluster.net.lan_rtt_s
        };
        let transfer_s = net::transfer_time_s(packed.wire_bytes, bw, rtt);
        per_fog_transfer_s[j] = transfer_s;
        per_fog_s[j] = transfer_s + pack_device_s;
        wire_total += packed.wire_bytes;
        raw_total += packed.raw_bytes;
    }

    CollectionResult {
        per_fog_s,
        per_fog_transfer_s,
        unpack_s,
        wire_bytes: wire_total,
        raw_bytes: raw_total,
        features,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{DaqConfig, IntervalScheme, DEFAULT_BITS};
    use crate::fog::Cluster;
    use crate::graph::generate;
    use crate::net::NetKind;

    fn setup() -> (Graph, Vec<f32>) {
        let (mut g, _) = generate::sbm(400, 2000, 4, 0.85, 3);
        let mut rng = crate::util::rng::Rng::new(1);
        let feats: Vec<f32> = (0..400 * 16)
            .map(|_| if rng.bool(0.1) { 1.0 } else { 0.0 })
            .collect();
        g.feature_dim = 16;
        g.features = feats.clone();
        (g, feats)
    }

    #[test]
    fn co_reduces_wire_bytes_and_collection_time() {
        let (g, feats) = setup();
        let cluster = Cluster::testbed(NetKind::Cell4G);
        let assignment: Vec<u32> =
            (0..400).map(|v| (v % 6) as u32).collect();
        let cfg = DaqConfig::from_degrees(&g.degrees(),
                                          IntervalScheme::EqualMass,
                                          DEFAULT_BITS);
        let none = collect(&g, &feats, 16, &assignment, &cluster,
                           &Codec::None, 8, false);
        let co = collect(&g, &feats, 16, &assignment, &cluster,
                         &Codec::Daq(cfg), 8, false);
        assert!(co.wire_bytes < none.wire_bytes / 3);
        let max = |v: &Vec<f64>| {
            v.iter().cloned().fold(0f64, f64::max)
        };
        assert!(max(&co.per_fog_s) < max(&none.per_fog_s));
        // features must round-trip with small error
        let err: f32 = feats
            .iter()
            .zip(&co.features)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.02, "max err {err}");
    }

    #[test]
    fn wan_collection_is_slower_than_lan() {
        let (g, feats) = setup();
        let cloud = Cluster::cloud(NetKind::Cell4G);
        let fog = Cluster::testbed(NetKind::Cell4G);
        let all0 = vec![0u32; 400];
        let assignment: Vec<u32> = (0..400).map(|v| (v % 6) as u32).collect();
        let c = collect(&g, &feats, 16, &all0, &cloud, &Codec::None, 8, true);
        let f = collect(&g, &feats, 16, &assignment, &fog, &Codec::None, 8,
                        false);
        let maxt = |v: &Vec<f64>| v.iter().cloned().fold(0f64, f64::max);
        assert!(maxt(&c.per_fog_s) > maxt(&f.per_fog_s));
    }

    #[test]
    fn transfer_share_is_deterministic_and_bounded() {
        let (g, feats) = setup();
        let cluster = Cluster::testbed(NetKind::Wifi);
        let assignment: Vec<u32> =
            (0..400).map(|v| (v % 6) as u32).collect();
        let a = collect(&g, &feats, 16, &assignment, &cluster,
                        &Codec::None, 8, false);
        let b = collect(&g, &feats, 16, &assignment, &cluster,
                        &Codec::None, 8, false);
        // the analytic share is reproducible even though per_fog_s
        // carries measured packing compute
        assert_eq!(a.per_fog_transfer_s, b.per_fog_transfer_s);
        for (t, full) in a.per_fog_transfer_s.iter().zip(&a.per_fog_s) {
            assert!(t <= full);
            assert!(*t > 0.0);
        }
    }

    #[test]
    fn indexed_collect_matches_unindexed_bitwise() {
        let (g, feats) = setup();
        let cluster = Cluster::testbed(NetKind::Wifi);
        let assignment: Vec<u32> =
            (0..400).map(|v| (v % 6) as u32).collect();
        let idx = CollectionIndex::build(&g, &assignment, cluster.len());
        let full = collect(&g, &feats, 16, &assignment, &cluster,
                           &Codec::None, 8, false);
        let fast = collect_indexed(&g, &idx, &feats, 16, &cluster,
                                   &Codec::None, 8, false);
        // the analytic shares are pure functions of the inputs — the
        // indexed path must be bit-identical, not merely close
        assert_eq!(full.per_fog_transfer_s, fast.per_fog_transfer_s);
        assert_eq!(full.wire_bytes, fast.wire_bytes);
        assert_eq!(full.raw_bytes, fast.raw_bytes);
        assert_eq!(full.features, fast.features);
    }

    #[test]
    fn index_partitions_and_degrees_are_consistent() {
        let (g, _) = setup();
        let assignment: Vec<u32> =
            (0..400).map(|v| (v % 3) as u32).collect();
        let idx = CollectionIndex::build(&g, &assignment, 5);
        let total: usize = idx.by_fog.iter().map(|v| v.len()).sum();
        assert_eq!(total, 400);
        assert_eq!(idx.active_fogs, 3);
        for (verts, degs) in idx.by_fog.iter().zip(&idx.degrees) {
            assert_eq!(verts.len(), degs.len());
            assert!(verts.windows(2).all(|w| w[0] < w[1]));
            for (&v, &d) in verts.iter().zip(degs) {
                assert_eq!(d, g.degree(v as usize) as u64);
            }
        }
        let empty = CollectionIndex::empty(5);
        assert_eq!(empty.active_fogs, 0);
        assert_eq!(empty.by_fog.len(), 5);
    }

    #[test]
    fn none_codec_passes_features_through_exactly() {
        let (g, feats) = setup();
        let cluster = Cluster::uniform_b(2, NetKind::Wifi);
        let assignment: Vec<u32> = (0..400).map(|v| (v % 2) as u32).collect();
        let r = collect(&g, &feats, 16, &assignment, &cluster,
                        &Codec::None, 4, false);
        assert_eq!(r.features, feats);
        assert_eq!(r.raw_bytes, 400 * 16 * 8);
        assert_eq!(r.wire_bytes, r.raw_bytes);
    }
}
