//! Communication optimizer substrate (paper §III-D): degree-aware
//! quantization, byte-plane shuffling, a from-scratch LZ4 block codec, and
//! the end-to-end pack/unpack pipeline (plus whole-payload comparators
//! for the ablation benches — real DEFLATE/zstd behind the
//! `ext-comparators` feature, an in-tree LZ4 stand-in otherwise).

pub mod bitshuffle;
pub mod lz4;
pub mod pipeline;
pub mod quantize;

pub use pipeline::{pack, unpack, Codec, Packed};
pub use quantize::{DaqConfig, IntervalScheme, DEFAULT_BITS};
