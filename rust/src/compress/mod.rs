//! Communication optimizer substrate (paper §III-D): degree-aware
//! quantization, byte-plane shuffling, a from-scratch LZ4 block codec, and
//! the end-to-end pack/unpack pipeline (plus DEFLATE/zstd comparators for
//! the ablation benches).

pub mod bitshuffle;
pub mod lz4;
pub mod pipeline;
pub mod quantize;

pub use pipeline::{pack, unpack, Codec, Packed};
pub use quantize::{DaqConfig, IntervalScheme, DEFAULT_BITS};
