//! From-scratch LZ4 block codec (the offline registry has no lz4 crate).
//!
//! Implements the LZ4 block format (token / literals / 2-byte offset /
//! match-length extension) with a greedy hash-chain compressor. The paper's
//! communication optimizer runs this over bit-shuffled quantized features
//! (§III-D "sparsity elimination ... LZ4 with bit shuffling").

const MIN_MATCH: usize = 4;
const LAST_LITERALS: usize = 5;
const MF_LIMIT: usize = 12; // matches may not start within the last 12 bytes
const HASH_LOG: usize = 16;

#[derive(Debug)]
pub enum Lz4Error {
    Malformed(&'static str),
}

impl std::fmt::Display for Lz4Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Lz4Error::Malformed(m) => write!(f, "malformed stream: {m}"),
        }
    }
}

impl std::error::Error for Lz4Error {}

#[inline]
fn hash(seq: u32) -> usize {
    (seq.wrapping_mul(2654435761) >> (32 - HASH_LOG)) as usize
}

#[inline]
fn read_u32(b: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]])
}

thread_local! {
    /// Reused match table: zeroing 256 KiB per call costs ~20% of
    /// compression time on small payloads (§Perf iteration 3).
    static TABLE: std::cell::RefCell<Vec<u32>> =
        std::cell::RefCell::new(vec![0u32; 1 << HASH_LOG]);
}

/// Compress `src` into an LZ4 block.
pub fn compress(src: &[u8]) -> Vec<u8> {
    let n = src.len();
    let mut out = Vec::with_capacity(n / 2 + 32);
    if n < MF_LIMIT + 1 {
        emit_last_literals(&mut out, src);
        return out;
    }
    TABLE.with(|t| {
        let mut table = t.borrow_mut();
        table.fill(0);
        compress_body(src, &mut out, &mut table);
    });
    out
}

fn compress_body(src: &[u8], out: &mut Vec<u8>, table: &mut [u32]) {
    let n = src.len();
    let mut anchor = 0usize;
    let mut i = 0usize;
    let match_limit = n - MF_LIMIT;
    while i < match_limit {
        let h = hash(read_u32(src, i));
        let cand = table[h] as usize;
        table[h] = (i + 1) as u32;
        if cand > 0
            && i - (cand - 1) <= 0xFFFF
            && read_u32(src, cand - 1) == read_u32(src, i)
        {
            let m = cand - 1;
            // extend match forward
            let mut len = MIN_MATCH;
            let max_len = n - LAST_LITERALS - i;
            while len < max_len && src[m + len] == src[i + len] {
                len += 1;
            }
            if len < MIN_MATCH {
                i += 1;
                continue;
            }
            emit_sequence(out, &src[anchor..i], (i - m) as u16, len);
            i += len;
            anchor = i;
            // prime the table with a couple of positions inside the match
            if i < match_limit {
                let h2 = hash(read_u32(src, i - 2));
                table[h2] = (i - 1) as u32;
            }
        } else {
            i += 1;
        }
    }
    emit_last_literals(out, &src[anchor..]);
}

fn emit_length(out: &mut Vec<u8>, mut len: usize) {
    while len >= 255 {
        out.push(255);
        len -= 255;
    }
    out.push(len as u8);
}

fn emit_sequence(out: &mut Vec<u8>, literals: &[u8], offset: u16,
                 match_len: usize) {
    debug_assert!(match_len >= MIN_MATCH);
    debug_assert!(offset > 0);
    let lit_len = literals.len();
    let ml = match_len - MIN_MATCH;
    let token = (lit_len.min(15) as u8) << 4 | ml.min(15) as u8;
    out.push(token);
    if lit_len >= 15 {
        emit_length(out, lit_len - 15);
    }
    out.extend_from_slice(literals);
    out.extend_from_slice(&offset.to_le_bytes());
    if ml >= 15 {
        emit_length(out, ml - 15);
    }
}

fn emit_last_literals(out: &mut Vec<u8>, literals: &[u8]) {
    let lit_len = literals.len();
    out.push((lit_len.min(15) as u8) << 4);
    if lit_len >= 15 {
        emit_length(out, lit_len - 15);
    }
    out.extend_from_slice(literals);
}

/// Decompress an LZ4 block (output size not known in advance).
pub fn decompress(src: &[u8]) -> Result<Vec<u8>, Lz4Error> {
    let mut out: Vec<u8> = Vec::with_capacity(src.len() * 3);
    let mut i = 0usize;
    let n = src.len();
    loop {
        if i >= n {
            if n == 0 {
                return Ok(out); // empty stream = empty payload
            }
            return Err(Lz4Error::Malformed("missing token"));
        }
        let token = src[i];
        i += 1;
        // literals
        let mut lit_len = (token >> 4) as usize;
        if lit_len == 15 {
            loop {
                let b = *src.get(i).ok_or(Lz4Error::Malformed(
                    "truncated literal length",
                ))?;
                i += 1;
                lit_len += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        if i + lit_len > n {
            return Err(Lz4Error::Malformed("truncated literals"));
        }
        out.extend_from_slice(&src[i..i + lit_len]);
        i += lit_len;
        if i == n {
            return Ok(out); // last sequence has no match part
        }
        // match
        if i + 2 > n {
            return Err(Lz4Error::Malformed("truncated offset"));
        }
        let offset = u16::from_le_bytes([src[i], src[i + 1]]) as usize;
        i += 2;
        if offset == 0 || offset > out.len() {
            return Err(Lz4Error::Malformed("bad offset"));
        }
        let mut match_len = (token & 0x0F) as usize;
        if match_len == 15 {
            loop {
                let b = *src.get(i).ok_or(Lz4Error::Malformed(
                    "truncated match length",
                ))?;
                i += 1;
                match_len += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        let match_len = match_len + MIN_MATCH;
        // overlapping copy (byte-by-byte semantics)
        let start = out.len() - offset;
        for k in 0..match_len {
            let b = out[start + k];
            out.push(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::{forall_shrink, shrink_vec};

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let d = decompress(&c).unwrap();
        assert_eq!(d, data, "roundtrip failed for len {}", data.len());
    }

    #[test]
    fn roundtrip_edge_cases() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"hello world");
        roundtrip(&[0u8; 13]);
        roundtrip(&vec![7u8; 100_000]);
        roundtrip(b"abcabcabcabcabcabcabcabcabcabcabcabcabcabc");
    }

    #[test]
    fn compresses_repetitive_data_hard() {
        let data = vec![42u8; 10_000];
        let c = compress(&data);
        assert!(c.len() < 100, "len {}", c.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn compresses_sparse_features_like_siot() {
        // one-hot-like: mostly zeros with scattered ones
        let mut rng = Rng::new(4);
        let mut data = vec![0u8; 52 * 4 * 1000];
        for _ in 0..2000 {
            let idx = rng.usize_below(data.len());
            data[idx] = 0x3F; // exponent byte of 1.0f32
        }
        let c = compress(&data);
        assert!(
            (c.len() as f64) < data.len() as f64 * 0.15,
            "ratio {}",
            c.len() as f64 / data.len() as f64
        );
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn incompressible_data_grows_boundedly() {
        let mut rng = Rng::new(5);
        let data: Vec<u8> =
            (0..10_000).map(|_| rng.below(256) as u8).collect();
        let c = compress(&data);
        assert!(c.len() < data.len() + data.len() / 128 + 64);
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn property_roundtrip_random_structured() {
        forall_shrink(
            11,
            120,
            |r| {
                let n = r.usize_below(3000);
                let mut v = Vec::with_capacity(n);
                // mix of runs and noise — exercises match emitter paths
                while v.len() < n {
                    if r.bool(0.5) {
                        let b = r.below(4) as u8;
                        let run = 1 + r.usize_below(40);
                        v.extend(std::iter::repeat(b).take(run.min(n - v.len())));
                    } else {
                        v.push(r.below(256) as u8);
                    }
                }
                v
            },
            shrink_vec,
            |data| decompress(&compress(data)).map(|d| d == *data)
                .unwrap_or(false),
        );
    }

    #[test]
    fn decompress_rejects_malformed() {
        assert!(decompress(&[0x10]).is_err()); // promises 1 literal, has 0
        assert!(decompress(&[0x0F, 0x00]).is_err()); // match with no output
        // bad offset: token 0 literals + match offset 5 with empty history
        assert!(decompress(&[0x00, 0x05, 0x00]).is_err());
    }

    #[test]
    fn long_literal_and_match_extensions() {
        // >15 literals followed by >15+4 match
        let mut data = Vec::new();
        let mut rng = Rng::new(6);
        for _ in 0..300 {
            data.push(rng.below(250) as u8);
        }
        let pattern: Vec<u8> = data[..100].to_vec();
        data.extend_from_slice(&pattern); // long match far back
        roundtrip(&data);
    }
}
