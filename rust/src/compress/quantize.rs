//! Degree-aware quantization (DAQ) — paper §III-D, Fig. 9, Theorem 2.
//!
//! Each vertex's feature vector is linearly quantized to a bitwidth chosen
//! by the vertex's degree: higher-degree vertices assimilate more neighbor
//! information during aggregation, smoothing their quantization error, so
//! they tolerate LOWER bitwidths. The degree triplet ⟨D1, D2, D3⟩ splits
//! vertices into four intervals with bitwidths ⟨q0, q1, q2, q3⟩
//! (default ⟨64, 32, 16, 8⟩; source features are 64-bit sensor readings).

use crate::util::stats::EmpiricalCdf;

/// Bitwidth assignment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct DaqConfig {
    /// Degree interval boundaries ⟨D1, D2, D3⟩ (right-open intervals).
    pub thresholds: [u64; 3],
    /// Bits for each interval ⟨q0, q1, q2, q3⟩, low-degree first.
    pub bits: [u8; 4],
}

/// How interval boundaries are derived from the degree distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntervalScheme {
    /// Quartiles of the degree distribution (equal vertex mass — the
    /// effective default for power-law IoT graphs).
    EqualMass,
    /// Equal-width intervals over [0, D_max].
    EqualWidth,
}

pub const DEFAULT_BITS: [u8; 4] = [64, 32, 16, 8];

impl DaqConfig {
    /// Derive ⟨D1,D2,D3⟩ from a graph's degree multiset.
    pub fn from_degrees(degrees: &[u32], scheme: IntervalScheme,
                        bits: [u8; 4]) -> DaqConfig {
        let cdf = EmpiricalCdf::new(
            degrees.iter().map(|&d| d as u64).collect(),
        );
        let thresholds = match scheme {
            IntervalScheme::EqualMass => [
                cdf.quantile(0.25).max(1),
                cdf.quantile(0.50).max(2),
                cdf.quantile(0.75).max(3),
            ],
            IntervalScheme::EqualWidth => {
                let dmax = cdf.max().max(4);
                [dmax / 4, dmax / 2, 3 * dmax / 4]
            }
        };
        // enforce strictly increasing thresholds
        let mut t = thresholds;
        if t[1] <= t[0] {
            t[1] = t[0] + 1;
        }
        if t[2] <= t[1] {
            t[2] = t[1] + 1;
        }
        DaqConfig { thresholds: t, bits }
    }

    /// Bitwidth for a vertex of degree `d`.
    pub fn bits_for_degree(&self, d: u64) -> u8 {
        let [d1, d2, d3] = self.thresholds;
        if d < d1 {
            self.bits[0]
        } else if d < d2 {
            self.bits[1]
        } else if d < d3 {
            self.bits[2]
        } else {
            self.bits[3]
        }
    }

    /// Theorem 2: compression ratio
    /// (1/Q)·[q3 − Σ_i F_D(D_i)(q_i − q_{i−1})], Q = source bitwidth.
    pub fn theorem2_ratio(&self, degrees: &[u32], source_bits: f64) -> f64 {
        let cdf = EmpiricalCdf::new(
            degrees.iter().map(|&d| d as u64).collect(),
        );
        let q = [
            self.bits[0] as f64,
            self.bits[1] as f64,
            self.bits[2] as f64,
            self.bits[3] as f64,
        ];
        let mut acc = q[3];
        for i in 1..=3 {
            // F_D is P(D <= d); intervals are right-open, so use D_i - 1
            let f = cdf.at(self.thresholds[i - 1].saturating_sub(1));
            acc -= f * (q[i] - q[i - 1]);
        }
        acc / source_bits
    }
}

/// A quantized feature vector: linear quantization over [min, max] with
/// 2^bits levels (bits in {8, 16}); 32/64-bit vertices keep float payloads.
#[derive(Clone, Debug)]
pub struct QuantizedVertex {
    pub bits: u8,
    pub min: f32,
    pub scale: f32,
    pub payload: Vec<u8>,
    pub dims: usize,
}

/// Per-vertex wire size in bytes (payload + 9-byte header: bits + min +
/// scale; matches the packing deployed on end devices, §III-D).
pub fn wire_bytes(dims: usize, bits: u8) -> usize {
    9 + dims * bits as usize / 8
}

pub fn quantize(features: &[f32], bits: u8) -> QuantizedVertex {
    let dims = features.len();
    match bits {
        64 => {
            // features originate as f64 readings: ship full doubles
            let mut payload = Vec::with_capacity(dims * 8);
            for &x in features {
                payload.extend_from_slice(&(x as f64).to_le_bytes());
            }
            QuantizedVertex { bits, min: 0.0, scale: 1.0, payload, dims }
        }
        32 => {
            let mut payload = Vec::with_capacity(dims * 4);
            for &x in features {
                payload.extend_from_slice(&x.to_le_bytes());
            }
            QuantizedVertex { bits, min: 0.0, scale: 1.0, payload, dims }
        }
        16 | 8 => {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &x in features {
                lo = lo.min(x);
                hi = hi.max(x);
            }
            if !lo.is_finite() {
                lo = 0.0;
                hi = 0.0;
            }
            let levels = ((1u32 << bits) - 1) as f32;
            let range = (hi - lo).max(1e-12);
            let scale = range / levels;
            let mut payload =
                Vec::with_capacity(dims * bits as usize / 8);
            for &x in features {
                let q = ((x - lo) / scale).round().clamp(0.0, levels);
                if bits == 16 {
                    payload.extend_from_slice(&(q as u16).to_le_bytes());
                } else {
                    payload.push(q as u8);
                }
            }
            QuantizedVertex { bits, min: lo, scale, payload, dims }
        }
        other => panic!("unsupported bitwidth {other}"),
    }
}

pub fn dequantize(q: &QuantizedVertex) -> Vec<f32> {
    match q.bits {
        64 => q
            .payload
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()) as f32)
            .collect(),
        32 => q
            .payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        16 => q
            .payload
            .chunks_exact(2)
            .map(|c| {
                q.min + u16::from_le_bytes(c.try_into().unwrap()) as f32
                    * q.scale
            })
            .collect(),
        8 => q.payload.iter().map(|&b| q.min + b as f32 * q.scale).collect(),
        _ => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bits_for_degree_respects_intervals() {
        let cfg = DaqConfig { thresholds: [4, 8, 16], bits: DEFAULT_BITS };
        assert_eq!(cfg.bits_for_degree(0), 64);
        assert_eq!(cfg.bits_for_degree(3), 64);
        assert_eq!(cfg.bits_for_degree(4), 32);
        assert_eq!(cfg.bits_for_degree(7), 32);
        assert_eq!(cfg.bits_for_degree(8), 16);
        assert_eq!(cfg.bits_for_degree(16), 8);
        assert_eq!(cfg.bits_for_degree(1000), 8);
    }

    #[test]
    fn equal_mass_thresholds_split_quartiles() {
        let degrees: Vec<u32> = (1..=100).collect();
        let cfg = DaqConfig::from_degrees(
            &degrees,
            IntervalScheme::EqualMass,
            DEFAULT_BITS,
        );
        // quartiles of 1..=100
        assert!(cfg.thresholds[0] >= 24 && cfg.thresholds[0] <= 27);
        assert!(cfg.thresholds[1] >= 49 && cfg.thresholds[1] <= 52);
        assert!(cfg.thresholds[2] >= 74 && cfg.thresholds[2] <= 77);
    }

    #[test]
    fn roundtrip_error_bounds() {
        let mut rng = Rng::new(3);
        let feats: Vec<f32> =
            (0..64).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        for &bits in &[64u8, 32, 16, 8] {
            let q = quantize(&feats, bits);
            let back = dequantize(&q);
            assert_eq!(back.len(), feats.len());
            let max_err = feats
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let range = 4.0 * 2.0; // ~spread of the samples
            let bound = match bits {
                64 | 32 => 1e-6,
                16 => range / 65535.0 * 1.01,
                8 => range / 255.0 * 1.01,
                _ => unreachable!(),
            };
            assert!(
                max_err <= bound,
                "bits={bits} err={max_err} bound={bound}"
            );
        }
    }

    #[test]
    fn wire_bytes_shrink_with_bits() {
        assert!(wire_bytes(52, 8) < wire_bytes(52, 16));
        assert!(wire_bytes(52, 16) < wire_bytes(52, 32));
        assert!(wire_bytes(52, 32) < wire_bytes(52, 64));
        assert_eq!(wire_bytes(52, 8), 9 + 52);
    }

    #[test]
    fn theorem2_matches_actual_payload_ratio() {
        // power-law-ish degrees
        let mut rng = Rng::new(9);
        let degrees: Vec<u32> = (0..5000)
            .map(|_| {
                let u = rng.f64();
                ((1.0 / (1.0 - u)).powf(0.7) as u32).min(500)
            })
            .collect();
        let cfg = DaqConfig::from_degrees(
            &degrees,
            IntervalScheme::EqualMass,
            DEFAULT_BITS,
        );
        let predicted = cfg.theorem2_ratio(&degrees, 64.0);
        // actual: average bits over vertices / 64 (payload only)
        let total_bits: f64 = degrees
            .iter()
            .map(|&d| cfg.bits_for_degree(d as u64) as f64)
            .sum();
        let actual = total_bits / degrees.len() as f64 / 64.0;
        assert!(
            (predicted - actual).abs() < 0.02,
            "thm2 {predicted} vs actual {actual}"
        );
        // meaningful compression on skewed graphs
        assert!(predicted < 0.75);
    }

    #[test]
    fn constant_features_quantize_cleanly() {
        let q = quantize(&[1.5; 10], 8);
        let back = dequantize(&q);
        assert!(back.iter().all(|&x| (x - 1.5).abs() < 1e-6));
    }

    #[test]
    #[should_panic(expected = "unsupported bitwidth")]
    fn rejects_weird_bitwidth() {
        quantize(&[1.0], 12);
    }
}
