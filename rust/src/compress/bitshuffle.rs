//! Byte-plane shuffling for numeric payloads: regroups the k-th byte of
//! every element together so LZ4 sees long same-byte runs (exponent bytes
//! of similar floats, zero high bytes of small integers). The classic
//! "bit shuffling" preconditioner the paper pairs with LZ4 (§III-D).

/// Shuffle `data` (elements of `width` bytes) into byte planes.
/// Trailing bytes (len % width) are appended unshuffled.
pub fn shuffle(data: &[u8], width: usize) -> Vec<u8> {
    assert!(width >= 1);
    let n_elems = data.len() / width;
    let body = n_elems * width;
    let mut out = Vec::with_capacity(data.len());
    for plane in 0..width {
        for e in 0..n_elems {
            out.push(data[e * width + plane]);
        }
    }
    out.extend_from_slice(&data[body..]);
    out
}

/// Inverse of `shuffle`.
pub fn unshuffle(data: &[u8], width: usize) -> Vec<u8> {
    assert!(width >= 1);
    let n_elems = data.len() / width;
    let body = n_elems * width;
    let mut out = vec![0u8; data.len()];
    for plane in 0..width {
        for e in 0..n_elems {
            out[e * width + plane] = data[plane * n_elems + e];
        }
    }
    out[body..].copy_from_slice(&data[body..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_exact_and_ragged() {
        let mut rng = Rng::new(2);
        for &(len, width) in
            &[(0usize, 4usize), (3, 4), (16, 4), (17, 4), (100, 8), (7, 2)]
        {
            let data: Vec<u8> =
                (0..len).map(|_| rng.below(256) as u8).collect();
            let s = shuffle(&data, width);
            assert_eq!(s.len(), data.len());
            assert_eq!(unshuffle(&s, width), data);
        }
    }

    #[test]
    fn shuffle_groups_planes() {
        // elements 0x11223344 repeated: plane grouping makes runs
        let data = [0x44u8, 0x33, 0x22, 0x11, 0x44, 0x33, 0x22, 0x11];
        let s = shuffle(&data, 4);
        assert_eq!(s, [0x44, 0x44, 0x33, 0x33, 0x22, 0x22, 0x11, 0x11]);
    }

    #[test]
    fn improves_lz4_on_float_payloads() {
        use crate::compress::lz4;
        let mut rng = Rng::new(3);
        // similar-magnitude floats: same exponent byte, noisy mantissas
        let mut data = Vec::new();
        for _ in 0..4000 {
            let x = 1.0f32 + rng.f32() * 0.01;
            data.extend_from_slice(&x.to_le_bytes());
        }
        let plain = lz4::compress(&data).len();
        let shuffled = lz4::compress(&shuffle(&data, 4)).len();
        assert!(
            (shuffled as f64) < plain as f64 * 0.8,
            "shuffled {shuffled} vs plain {plain}"
        );
    }
}
