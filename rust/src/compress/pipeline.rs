//! The communication optimizer (CO, paper §III-D): degree-aware
//! quantization → byte-plane shuffling → LZ4, end to end.
//!
//! Packing runs on the data-source side (devices), unpacking on fog nodes;
//! both ends derive each vertex's bitwidth deterministically from the
//! registered degree metadata, so no per-vertex bit tags travel on the
//! wire — only the four compressed bit-planes streams.

use super::bitshuffle;
use super::lz4;
use super::quantize::{dequantize, quantize, DaqConfig, QuantizedVertex};

/// Feature compression policy for data collection.
#[derive(Clone, Debug, PartialEq)]
pub enum Codec {
    /// Raw f64 readings, no compression (cloud/fog baselines).
    None,
    /// Degree-aware quantization + shuffle + LZ4 (Fograph's CO).
    Daq(DaqConfig),
    /// Uniform bitwidth + shuffle + LZ4 (Table V's "Uni. 8-bit" baseline).
    Uniform(u8),
    /// LZ4-only sparsity elimination (CO ablation: no quantizer).
    Lz4Only,
}

/// One packed upload unit (a device's or partition's feature block).
#[derive(Clone, Debug)]
pub struct Packed {
    /// Bytes that travel on the wire.
    pub wire_bytes: usize,
    /// Bytes before compression (after quantization).
    pub quantized_bytes: usize,
    /// Raw f64 source payload bytes (Q = 64 per Theorem 2).
    pub raw_bytes: usize,
    streams: Vec<(u8, Vec<u8>)>, // (bits, lz4 blob) per bitwidth group
    headers: Vec<u8>,            // lz4 blob of per-vertex (min, scale)
    dims: usize,
    bits_per_vertex: Vec<u8>,
}

impl Packed {
    pub fn compression_ratio(&self) -> f64 {
        self.wire_bytes as f64 / self.raw_bytes.max(1) as f64
    }
}

/// Pack `rows` (per-vertex feature slices) whose degrees are `degrees`.
pub fn pack(rows: &[&[f32]], degrees: &[u64], codec: &Codec) -> Packed {
    assert_eq!(rows.len(), degrees.len());
    let dims = rows.first().map(|r| r.len()).unwrap_or(0);
    let raw_bytes = rows.len() * dims * 8;

    let bits_per_vertex: Vec<u8> = match codec {
        Codec::None => vec![64; rows.len()],
        Codec::Lz4Only => vec![64; rows.len()],
        Codec::Uniform(b) => vec![*b; rows.len()],
        Codec::Daq(cfg) => degrees
            .iter()
            .map(|&d| cfg.bits_for_degree(d))
            .collect(),
    };

    if matches!(codec, Codec::None) {
        return Packed {
            wire_bytes: raw_bytes,
            quantized_bytes: raw_bytes,
            raw_bytes,
            streams: Vec::new(),
            headers: Vec::new(),
            dims,
            bits_per_vertex,
        };
    }

    // group payloads by bitwidth for coherent byte planes
    let mut groups: [Vec<u8>; 4] = Default::default(); // 64,32,16,8
    let mut headers_raw: Vec<u8> = Vec::new();
    let mut quantized_bytes = 0usize;
    for (row, &bits) in rows.iter().zip(&bits_per_vertex) {
        let q: QuantizedVertex = quantize(row, bits);
        quantized_bytes += q.payload.len() + 8;
        headers_raw.extend_from_slice(&q.min.to_le_bytes());
        headers_raw.extend_from_slice(&q.scale.to_le_bytes());
        groups[group_of(bits)].extend_from_slice(&q.payload);
    }
    let mut streams = Vec::new();
    let mut wire = 16; // stream table header
    for (gi, payload) in groups.iter().enumerate() {
        if payload.is_empty() {
            continue;
        }
        let bits = bits_of(gi);
        let shuffled = bitshuffle::shuffle(payload, bits as usize / 8);
        let blob = lz4::compress(&shuffled);
        wire += blob.len() + 8;
        streams.push((bits, blob));
    }
    let headers = lz4::compress(&bitshuffle::shuffle(&headers_raw, 4));
    wire += headers.len();
    Packed {
        wire_bytes: wire,
        quantized_bytes,
        raw_bytes,
        streams,
        headers,
        dims,
        bits_per_vertex,
    }
}

/// Unpack back to dequantized f32 rows (fog side, before inference).
pub fn unpack(p: &Packed, rows_out: &mut Vec<Vec<f32>>)
              -> Result<(), lz4::Lz4Error> {
    rows_out.clear();
    if p.streams.is_empty() {
        // Codec::None — caller retains original rows; nothing to do.
        return Ok(());
    }
    let headers_raw =
        bitshuffle::unshuffle(&lz4::decompress(&p.headers)?, 4);
    // per-group cursors
    let mut group_data: [Vec<u8>; 4] = Default::default();
    for (bits, blob) in &p.streams {
        let raw = lz4::decompress(blob)?;
        group_data[group_of(*bits)] =
            bitshuffle::unshuffle(&raw, *bits as usize / 8);
    }
    let mut cursors = [0usize; 4];
    for (vi, &bits) in p.bits_per_vertex.iter().enumerate() {
        let g = group_of(bits);
        let bytes = p.dims * bits as usize / 8;
        let payload =
            group_data[g][cursors[g]..cursors[g] + bytes].to_vec();
        cursors[g] += bytes;
        let min = f32::from_le_bytes(
            headers_raw[vi * 8..vi * 8 + 4].try_into().unwrap(),
        );
        let scale = f32::from_le_bytes(
            headers_raw[vi * 8 + 4..vi * 8 + 8].try_into().unwrap(),
        );
        let q = QuantizedVertex { bits, min, scale, payload, dims: p.dims };
        rows_out.push(dequantize(&q));
    }
    Ok(())
}

fn group_of(bits: u8) -> usize {
    match bits {
        64 => 0,
        32 => 1,
        16 => 2,
        8 => 3,
        _ => panic!("bad bits {bits}"),
    }
}

fn bits_of(group: usize) -> u8 {
    [64u8, 32, 16, 8][group]
}

// ---- comparator codecs for the CO ablation bench --------------------------
//
// The real DEFLATE / zstd comparators need the external `flate2` and
// `zstd` crates, which are not vendored in this offline tree. Like the
// PJRT path they sit behind an off-by-default cargo feature
// (`ext-comparators`); the default build substitutes the in-tree LZ4
// codec as a size-only stand-in so the ablation table keeps a
// whole-payload general-purpose baseline either way.

/// Labels for the two comparator rows in the CO ablation table — the
/// stand-in build must not masquerade as the real codecs.
#[cfg(feature = "ext-comparators")]
pub const COMPARATOR_LABELS: [&str; 2] =
    ["DEFLATE (whole payload)", "zstd-1 (whole payload)"];
#[cfg(not(feature = "ext-comparators"))]
pub const COMPARATOR_LABELS: [&str; 2] = [
    "LZ4 stand-in for DEFLATE (whole payload)",
    "LZ4 stand-in for zstd-1 (whole payload)",
];

/// DEFLATE comparator (flate2; needs `--features ext-comparators`
/// with the crate vendored).
#[cfg(feature = "ext-comparators")]
pub fn deflate_size(data: &[u8]) -> usize {
    use flate2::write::DeflateEncoder;
    use flate2::Compression;
    use std::io::Write;
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(data).unwrap();
    enc.finish().unwrap().len()
}

/// zstd comparator (needs `--features ext-comparators` with the crate
/// vendored).
#[cfg(feature = "ext-comparators")]
pub fn zstd_size(data: &[u8]) -> usize {
    zstd::bulk::compress(data, 1).map(|v| v.len()).unwrap_or(data.len())
}

/// Offline stand-in for the DEFLATE comparator: whole-payload size
/// under the in-tree LZ4 block codec (same LZ77 family, fast preset).
#[cfg(not(feature = "ext-comparators"))]
pub fn deflate_size(data: &[u8]) -> usize {
    lz4::compress(data).len()
}

/// Offline stand-in for the zstd comparator — see [`deflate_size`].
#[cfg(not(feature = "ext-comparators"))]
pub fn zstd_size(data: &[u8]) -> usize {
    lz4::compress(data).len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quantize::{DaqConfig, DEFAULT_BITS};
    use crate::util::rng::Rng;

    fn onehotish_rows(n: usize, dims: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut r = vec![0f32; dims];
                r[rng.usize_below(dims)] = 1.0;
                r[rng.usize_below(dims)] = 1.0;
                r
            })
            .collect()
    }

    fn powerlaw_degrees(n: usize, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let u = rng.f64();
                ((1.0 / (1.0 - u)).powf(0.8) as u64).min(400)
            })
            .collect()
    }

    fn cfg_for(degrees: &[u64]) -> DaqConfig {
        let d32: Vec<u32> = degrees.iter().map(|&d| d as u32).collect();
        DaqConfig::from_degrees(
            &d32,
            super::super::quantize::IntervalScheme::EqualMass,
            DEFAULT_BITS,
        )
    }

    #[test]
    fn daq_roundtrip_with_bounded_error() {
        let rows = onehotish_rows(500, 52, 1);
        let degrees = powerlaw_degrees(500, 2);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let codec = Codec::Daq(cfg_for(&degrees));
        let p = pack(&refs, &degrees, &codec);
        let mut out = Vec::new();
        unpack(&p, &mut out).unwrap();
        assert_eq!(out.len(), 500);
        for (orig, back) in rows.iter().zip(&out) {
            for (a, b) in orig.iter().zip(back) {
                assert!((a - b).abs() < 0.01, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn daq_compresses_sparse_features_hard() {
        let rows = onehotish_rows(2000, 52, 3);
        let degrees = powerlaw_degrees(2000, 4);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let p = pack(&refs, &degrees, &Codec::Daq(cfg_for(&degrees)));
        assert!(
            p.compression_ratio() < 0.15,
            "ratio {}",
            p.compression_ratio()
        );
    }

    #[test]
    fn ratio_ordering_none_gt_lz4_gt_daq() {
        let rows = onehotish_rows(1000, 52, 5);
        let degrees = powerlaw_degrees(1000, 6);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let none = pack(&refs, &degrees, &Codec::None);
        let lz4only = pack(&refs, &degrees, &Codec::Lz4Only);
        let daq = pack(&refs, &degrees, &Codec::Daq(cfg_for(&degrees)));
        assert!(none.wire_bytes > lz4only.wire_bytes);
        assert!(lz4only.wire_bytes > daq.wire_bytes);
    }

    #[test]
    fn uniform8_is_smaller_but_noisier_than_daq() {
        let mut rng = Rng::new(7);
        let rows: Vec<Vec<f32>> = (0..800)
            .map(|_| (0..36).map(|_| rng.normal_f32(200.0, 80.0)).collect())
            .collect();
        let degrees = powerlaw_degrees(800, 8);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let daq = pack(&refs, &degrees, &Codec::Daq(cfg_for(&degrees)));
        let uni = pack(&refs, &degrees, &Codec::Uniform(8));
        assert!(uni.wire_bytes <= daq.wire_bytes);
        // error: uniform-8 worse on low-degree vertices than DAQ overall
        let mut daq_out = Vec::new();
        let mut uni_out = Vec::new();
        unpack(&daq, &mut daq_out).unwrap();
        unpack(&uni, &mut uni_out).unwrap();
        let err = |outs: &Vec<Vec<f32>>| -> f64 {
            rows.iter()
                .zip(outs)
                .flat_map(|(a, b)| {
                    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64)
                })
                .sum::<f64>()
        };
        assert!(err(&daq_out) < err(&uni_out));
    }

    #[test]
    fn empty_input_is_fine() {
        let refs: Vec<&[f32]> = Vec::new();
        let p = pack(&refs, &[], &Codec::Uniform(8));
        let mut out = Vec::new();
        unpack(&p, &mut out).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn comparators_run() {
        let data = vec![1u8; 4096];
        assert!(deflate_size(&data) < 256);
        assert!(zstd_size(&data) < 256);
    }

    // ---- scale-tier shapes (spill-store round trips) ----------------------

    fn dense_rows(n: usize, dims: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (0..dims).map(|_| rng.normal_f32(0.0, 5.0)).collect()
            })
            .collect()
    }

    #[test]
    fn lz4only_roundtrip_is_bit_exact_on_wide_blocks() {
        // the spill store's quantize-off invariant, at a scale-tier
        // shape: wide dense f32 rows, not the small one-hot fixtures
        let rows = dense_rows(128, 256, 21);
        let degrees: Vec<u64> = (0..128).map(|i| 1 + i as u64).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let p = pack(&refs, &degrees, &Codec::Lz4Only);
        let mut out = Vec::new();
        unpack(&p, &mut out).unwrap();
        assert_eq!(out.len(), 128);
        for (orig, back) in rows.iter().zip(&out) {
            let a: Vec<u32> = orig.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn one_row_partition_roundtrips_under_every_codec() {
        let rows = dense_rows(1, 64, 22);
        let degrees = vec![7u64];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        for codec in [
            Codec::Lz4Only,
            Codec::Uniform(8),
            Codec::Daq(cfg_for(&degrees)),
        ] {
            let p = pack(&refs, &degrees, &codec);
            let mut out = Vec::new();
            unpack(&p, &mut out).unwrap();
            assert_eq!(out.len(), 1, "{codec:?}");
            assert_eq!(out[0].len(), 64, "{codec:?}");
            for (a, b) in rows[0].iter().zip(&out[0]) {
                assert!((a - b).abs() < 0.1, "{codec:?}: {a} vs {b}");
            }
        }
        // quantize off: additionally bit-exact
        let p = pack(&refs, &degrees, &Codec::Lz4Only);
        let mut out = Vec::new();
        unpack(&p, &mut out).unwrap();
        assert!(rows[0]
            .iter()
            .zip(&out[0])
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn zero_row_partition_roundtrips_under_every_codec() {
        let refs: Vec<&[f32]> = Vec::new();
        let degrees: Vec<u64> = Vec::new();
        for codec in [
            Codec::None,
            Codec::Lz4Only,
            Codec::Uniform(8),
            Codec::Daq(cfg_for(&[1])),
        ] {
            let p = pack(&refs, &degrees, &codec);
            let mut out = Vec::new();
            unpack(&p, &mut out).unwrap();
            assert!(out.is_empty(), "{codec:?}");
            assert_eq!(p.raw_bytes, 0, "{codec:?}");
        }
    }

    #[test]
    fn wide_block_quantizer_on_off_tradeoff_holds() {
        // quantize ON must shrink the wire; OFF must stay exact — the
        // two halves of the spill-store contract at one shape
        let rows = dense_rows(512, 128, 23);
        let degrees = powerlaw_degrees(512, 24);
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let exact = pack(&refs, &degrees, &Codec::Lz4Only);
        let lossy = pack(&refs, &degrees, &Codec::Uniform(8));
        assert!(lossy.wire_bytes < exact.wire_bytes);
        let mut exact_out = Vec::new();
        unpack(&exact, &mut exact_out).unwrap();
        assert!(rows
            .iter()
            .zip(&exact_out)
            .all(|(r, o)| {
                r.iter().zip(o).all(|(a, b)| a.to_bits() == b.to_bits())
            }));
    }
}
