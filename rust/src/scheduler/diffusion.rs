//! Diffusion-based placement adjustment (paper §III-F, Fig. 10): migrate
//! boundary vertices from the most-loaded to the least-loaded partition —
//! picking, per migration, the boundary vertex sharing the most neighbors
//! with the receiving side — until the estimated local balance meets the
//! tolerance λ.

use crate::graph::Graph;
use crate::profile::{Cardinality, PerfModel};

use super::indicator::skew_indicators;

/// Estimated per-fog execution times for an assignment under per-node
/// scaled models (capability × load folded into ω').
pub fn estimate_times(g: &Graph, assignment: &[u32], n: usize,
                      omegas: &[PerfModel]) -> Vec<f64> {
    let mut verts = vec![0usize; n];
    let mut edges = vec![0usize; n];
    for v in 0..g.num_vertices() {
        let j = assignment[v] as usize;
        verts[j] += 1;
        edges[j] += g.degree(v);
    }
    (0..n)
        .map(|j| omegas[j].predict(Cardinality::new(verts[j], edges[j])))
        .collect()
}

/// One pairwise diffusion between the currently most- and least-loaded
/// partitions. Returns the number of vertices migrated.
fn diffuse_pair(
    g: &Graph,
    assignment: &mut [u32],
    omegas: &[PerfModel],
    n: usize,
    lambda: f64,
    max_moves: usize,
) -> usize {
    let times = estimate_times(g, assignment, n, omegas);
    let mu = skew_indicators(&times);
    let hot = mu
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let cold = mu
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    if mu[hot] <= lambda || hot == cold {
        return 0;
    }
    let mut moved = 0usize;
    for _ in 0..max_moves {
        // boundary vertex of `hot` sharing the most neighbors with `cold`
        let mut best: Option<(usize, usize)> = None; // (vertex, shared)
        for v in 0..g.num_vertices() {
            if assignment[v] as usize != hot {
                continue;
            }
            let shared = g
                .neighbors(v)
                .iter()
                .filter(|&&u| assignment[u as usize] as usize == cold)
                .count();
            if shared > 0 {
                match best {
                    Some((_, s)) if s >= shared => {}
                    _ => best = Some((v, shared)),
                }
            }
        }
        let v = match best {
            Some((v, _)) => v,
            None => {
                // no boundary vertex: take any hot vertex (disconnected)
                match (0..g.num_vertices())
                    .find(|&v| assignment[v] as usize == hot)
                {
                    Some(v) => v,
                    None => break,
                }
            }
        };
        assignment[v] = cold as u32;
        moved += 1;
        // stop once estimated balance is restored
        let times = estimate_times(g, assignment, n, omegas);
        let mu = skew_indicators(&times);
        if mu[hot] <= lambda {
            break;
        }
    }
    moved
}

/// Full diffusion pass (paper: "continues for all unevenly-loaded nodes
/// until the overall estimated performance satisfies λ"). Returns total
/// migrations.
pub fn diffuse(
    g: &Graph,
    assignment: &mut [u32],
    omegas: &[PerfModel],
    n: usize,
    lambda: f64,
) -> usize {
    let mut total = 0usize;
    let budget = (g.num_vertices() / 10).max(8);
    for _round in 0..n * 4 {
        let moved =
            diffuse_pair(g, assignment, omegas, n, lambda, budget);
        total += moved;
        if moved == 0 {
            break;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    fn slowed_models(n: usize, slow_idx: usize, factor: f64)
                     -> Vec<PerfModel> {
        (0..n)
            .map(|j| {
                let m = if j == slow_idx { factor } else { 1.0 };
                PerfModel {
                    beta_v: 2e-6 * m,
                    beta_n: 4e-7 * m,
                    intercept: 1e-3 * m,
                    r2: 1.0,
                }
            })
            .collect()
    }

    #[test]
    fn diffusion_moves_load_off_the_hot_node() {
        let (g, _) = generate::sbm(1200, 6000, 6, 0.9, 3);
        let n = 3;
        let mut assignment: Vec<u32> =
            (0..1200).map(|v| (v * n / 1200) as u32).collect();
        // node 2 suddenly 3x slower
        let omegas = slowed_models(n, 2, 3.0);
        let before = estimate_times(&g, &assignment, n, &omegas);
        let mu_before = skew_indicators(&before);
        assert!(mu_before[2] > 1.3);
        let moved = diffuse(&g, &mut assignment, &omegas, n, 1.15);
        assert!(moved > 0);
        let after = estimate_times(&g, &assignment, n, &omegas);
        let mu_after = skew_indicators(&after);
        assert!(
            mu_after[2] < mu_before[2],
            "skew not reduced: {mu_before:?} -> {mu_after:?}"
        );
        // placement still valid
        assert!(assignment.iter().all(|&a| (a as usize) < n));
    }

    #[test]
    fn balanced_layout_is_left_alone() {
        let (g, _) = generate::sbm(600, 3000, 6, 0.9, 5);
        let n = 3;
        let mut assignment: Vec<u32> =
            (0..600).map(|v| (v * n / 600) as u32).collect();
        let omegas = slowed_models(n, 0, 1.0);
        let snapshot = assignment.clone();
        let moved = diffuse(&g, &mut assignment, &omegas, n, 1.25);
        assert_eq!(moved, 0);
        assert_eq!(assignment, snapshot);
    }

    #[test]
    fn migration_prefers_boundary_vertices() {
        // two communities; hot node holds community 0; migrated vertices
        // should be those adjacent to community 1's partition
        let (g, _) = generate::sbm(400, 2400, 2, 0.95, 7);
        let mut assignment: Vec<u32> =
            (0..400).map(|v| if v < 200 { 0 } else { 1 }).collect();
        let omegas = slowed_models(2, 0, 4.0);
        let before = assignment.clone();
        diffuse(&g, &mut assignment, &omegas, 2, 1.1);
        let migrated: Vec<usize> = (0..400)
            .filter(|&v| before[v] == 0 && assignment[v] == 1)
            .collect();
        assert!(!migrated.is_empty());
        // migrated vertices end up adjacent to the receiving partition
        // (each was a boundary vertex at its migration time, so in the
        // final layout it must touch partition 1)
        let boundary_frac = migrated
            .iter()
            .filter(|&&v| {
                g.neighbors(v)
                    .iter()
                    .any(|&u| assignment[u as usize] == 1 && u as usize != v)
            })
            .count() as f64
            / migrated.len() as f64;
        assert!(boundary_frac > 0.9, "boundary frac {boundary_frac}");
    }
}
