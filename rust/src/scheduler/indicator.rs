//! Load-balance indicator μ_j (paper Eq. 9): each fog's measured execution
//! time relative to the cluster mean. μ_j > λ flags node j as overloaded.

/// μ_j = T_j / mean_k(T_k). Returns all-1.0 for degenerate inputs.
pub fn skew_indicators(real_times: &[f64]) -> Vec<f64> {
    let n = real_times.len();
    if n == 0 {
        return Vec::new();
    }
    let mean: f64 = real_times.iter().sum::<f64>() / n as f64;
    if mean <= 0.0 {
        return vec![1.0; n];
    }
    real_times.iter().map(|&t| t / mean).collect()
}

/// Indices of nodes violating the imbalance tolerance λ.
pub fn overloaded(mu: &[f64], lambda: f64) -> Vec<usize> {
    debug_assert!(lambda >= 1.0, "λ must be ≥ 1");
    mu.iter()
        .enumerate()
        .filter(|(_, &m)| m > lambda)
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_cluster_has_unit_indicators() {
        let mu = skew_indicators(&[0.2, 0.2, 0.2, 0.2]);
        assert!(mu.iter().all(|&m| (m - 1.0).abs() < 1e-12));
        assert!(overloaded(&mu, 1.2).is_empty());
    }

    #[test]
    fn skewed_node_is_flagged() {
        let mu = skew_indicators(&[0.1, 0.1, 0.1, 0.5]);
        assert!(mu[3] > 2.0);
        assert_eq!(overloaded(&mu, 1.3), vec![3]);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(skew_indicators(&[]).is_empty());
        assert_eq!(skew_indicators(&[0.0, 0.0]), vec![1.0, 1.0]);
    }
}
