//! Dual-mode workload scheduler (paper Algorithm 2): update timings →
//! compute skew indicators → if tolerance λ is violated, choose between
//! the lightweight diffusion adjustment (few overloaded nodes) and a full
//! IEP replan (skew fraction above θ). Layout changes are computed
//! virtually and deployed at idle time.

use crate::fog::Cluster;
use crate::graph::{DatasetSpec, Graph};
use crate::partition::MultilevelParams;
use crate::placement::{self, MappingStrategy};
use crate::profile::PerfModel;
use crate::serving::pipeline::{default_cost_model, ServeOpts};

use super::diffusion;
use super::indicator::{overloaded, skew_indicators};

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Imbalance tolerance λ (> 1).
    pub lambda: f64,
    /// Skewness threshold θ ∈ (0, 1): fraction of overloaded nodes that
    /// escalates to global rescheduling (paper default 0.5).
    pub theta: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { lambda: 1.25, theta: 0.5 }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SchedulerDecision {
    /// Balanced within tolerance — keep the layout.
    Keep,
    /// Diffusion adjustment, with the number of migrated vertices.
    Diffused(usize),
    /// Full IEP replan.
    Replanned,
}

impl SchedulerDecision {
    /// Static trigger tag for `replan` flight-recorder spans (`None`
    /// for `Keep`, which records no control event).
    pub fn cause(&self) -> Option<&'static str> {
        match self {
            SchedulerDecision::Keep => None,
            SchedulerDecision::Diffused(_) => Some("diffusion"),
            SchedulerDecision::Replanned => Some("iep-replan"),
        }
    }
}

/// One scheduling step (Algorithm 2). `real_times` are the latest per-fog
/// measured execution times (from the online profilers via the metadata
/// server); `omegas` their η-scaled models. Mutates `assignment` in place
/// when an adjustment is applied.
#[allow(clippy::too_many_arguments)]
pub fn schedule(
    g: &Graph,
    spec: &DatasetSpec,
    cluster: &Cluster,
    opts: &ServeOpts,
    assignment: &mut Vec<u32>,
    real_times: &[f64],
    omegas: &[PerfModel],
    cfg: &SchedulerConfig,
) -> SchedulerDecision {
    let n = cluster.len();
    assert_eq!(real_times.len(), n);
    let mu = skew_indicators(real_times);
    let over = overloaded(&mu, cfg.lambda);
    if over.is_empty() {
        return SchedulerDecision::Keep;
    }
    let frac = over.len() as f64 / n as f64;
    if frac <= cfg.theta {
        let moved =
            diffusion::diffuse(g, assignment, omegas, n, cfg.lambda);
        SchedulerDecision::Diffused(moved)
    } else {
        let params = MultilevelParams {
            seed: opts.bgp_seed,
            ..Default::default()
        };
        let cost = default_cost_model(g, cluster, opts, spec);
        let plan = placement::plan(g, cluster, omegas, &cost,
                                   MappingStrategy::Lbap, &params);
        *assignment = plan.assignment;
        SchedulerDecision::Replanned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::fog::Cluster;
    use crate::net::NetKind;
    use crate::serving::Placement;

    fn setup() -> (Graph, DatasetSpec, Cluster, ServeOpts, Vec<PerfModel>) {
        let (mut g, _) = crate::graph::generate::sbm(800, 4000, 8, 0.9, 3);
        g.feature_dim = 8;
        g.features = vec![0.0; 800 * 8];
        let spec = DatasetSpec {
            name: "tiny",
            vertices: 800,
            edges: 4000,
            feature_dim: 8,
            classes: 2,
            duration: 1,
            window: 1,
            seed: 1,
        };
        let cluster = Cluster::case_study(NetKind::Wifi);
        let opts = ServeOpts::new("gcn", Placement::Iep, Codec::None);
        let omegas = vec![PerfModel::uncalibrated(); 4];
        (g, spec, cluster, opts, omegas)
    }

    fn balanced_assignment(n: usize, v: usize) -> Vec<u32> {
        (0..v).map(|x| (x * n / v) as u32).collect()
    }

    #[test]
    fn keeps_balanced_layout() {
        let (g, spec, cluster, opts, omegas) = setup();
        let mut a = balanced_assignment(4, 800);
        let d = schedule(&g, &spec, &cluster, &opts, &mut a,
                         &[0.1, 0.1, 0.1, 0.1], &omegas,
                         &SchedulerConfig::default());
        assert_eq!(d, SchedulerDecision::Keep);
        assert_eq!(d.cause(), None);
    }

    #[test]
    fn decision_causes_are_stable_tags() {
        assert_eq!(SchedulerDecision::Diffused(7).cause(),
                   Some("diffusion"));
        assert_eq!(SchedulerDecision::Replanned.cause(),
                   Some("iep-replan"));
    }

    #[test]
    fn single_hot_node_triggers_diffusion() {
        let (g, spec, cluster, opts, mut omegas) = setup();
        let mut a = balanced_assignment(4, 800);
        // node 3 reports 3x the mean; its scaled model reflects that
        omegas[3] = PerfModel {
            beta_v: omegas[3].beta_v * 3.0,
            beta_n: omegas[3].beta_n * 3.0,
            intercept: omegas[3].intercept * 3.0,
            r2: 1.0,
        };
        let d = schedule(&g, &spec, &cluster, &opts, &mut a,
                         &[0.1, 0.1, 0.1, 0.4], &omegas,
                         &SchedulerConfig::default());
        match d {
            SchedulerDecision::Diffused(m) => assert!(m > 0),
            other => panic!("expected diffusion, got {other:?}"),
        }
        // hot node lost vertices
        let count3 = a.iter().filter(|&&x| x == 3).count();
        assert!(count3 < 200);
    }

    #[test]
    fn widespread_skew_triggers_replan() {
        let (g, spec, cluster, opts, omegas) = setup();
        let mut a = balanced_assignment(4, 800);
        let before = a.clone();
        // 3 of 4 nodes overloaded (μ ≈ 1.26 > λ) -> frac 0.75 > θ=0.5
        let d = schedule(&g, &spec, &cluster, &opts, &mut a,
                         &[0.6, 0.6, 0.6, 0.1], &omegas,
                         &SchedulerConfig::default());
        assert_eq!(d, SchedulerDecision::Replanned);
        assert_ne!(a, before);
        // valid placement over 4 fogs
        assert!(a.iter().all(|&x| x < 4));
    }
}
