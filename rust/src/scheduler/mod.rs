//! Adaptive workload scheduling (paper §III-F): load-balance indicators
//! (Eq. 9), the lightweight diffusion-based adjustment (Fig. 10) and the
//! dual-mode scheduler (Algorithm 2) that escalates to a full IEP replan
//! when skew is widespread.

pub mod diffusion;
pub mod dual_mode;
pub mod indicator;

pub use diffusion::diffuse;
pub use dual_mode::{schedule, SchedulerConfig, SchedulerDecision};
pub use indicator::skew_indicators;
