//! Weighted working graph for the multilevel partitioner: vertex weights
//! carry collapsed-vertex counts through coarsening, edge weights carry
//! collapsed multi-edge multiplicities.

use crate::graph::Graph;

#[derive(Clone, Debug)]
pub struct WGraph {
    pub xadj: Vec<usize>,          // V+1
    pub adj: Vec<(u32, u64)>,      // (neighbor, edge weight)
    pub vwgt: Vec<u64>,            // vertex weights
}

impl WGraph {
    pub fn num_vertices(&self) -> usize {
        self.vwgt.len()
    }

    pub fn total_vwgt(&self) -> u64 {
        self.vwgt.iter().sum()
    }

    pub fn neighbors(&self, v: usize) -> &[(u32, u64)] {
        &self.adj[self.xadj[v]..self.xadj[v + 1]]
    }

    pub fn from_graph(g: &Graph) -> WGraph {
        let nv = g.num_vertices();
        let mut xadj = Vec::with_capacity(nv + 1);
        xadj.push(0usize);
        let mut adj = Vec::with_capacity(g.num_edges());
        for v in 0..nv {
            for &u in g.neighbors(v) {
                adj.push((u, 1u64));
            }
            xadj.push(adj.len());
        }
        WGraph { xadj, adj, vwgt: vec![1; nv] }
    }

    /// Contract according to `cmap` (vertex -> coarse id, ids dense 0..cn).
    pub fn contract(&self, cmap: &[u32], cn: usize) -> WGraph {
        let mut vwgt = vec![0u64; cn];
        for (v, &c) in cmap.iter().enumerate() {
            vwgt[c as usize] += self.vwgt[v];
        }
        // accumulate coarse adjacency
        let mut xadj = Vec::with_capacity(cn + 1);
        xadj.push(0usize);
        let mut adj: Vec<(u32, u64)> = Vec::with_capacity(self.adj.len() / 2);
        // bucket vertices by coarse id
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); cn];
        for (v, &c) in cmap.iter().enumerate() {
            members[c as usize].push(v as u32);
        }
        let mut acc: std::collections::HashMap<u32, u64> =
            std::collections::HashMap::new();
        for c in 0..cn {
            acc.clear();
            for &v in &members[c] {
                for &(u, w) in self.neighbors(v as usize) {
                    let cu = cmap[u as usize];
                    if cu as usize != c {
                        *acc.entry(cu).or_insert(0) += w;
                    }
                }
            }
            let mut entries: Vec<(u32, u64)> =
                acc.iter().map(|(&k, &v)| (k, v)).collect();
            entries.sort_unstable();
            adj.extend(entries);
            xadj.push(adj.len());
        }
        WGraph { xadj, adj, vwgt }
    }
}

/// Edge-cut of an assignment (sum of weights of edges crossing parts;
/// each undirected edge counted once).
pub fn edge_cut(g: &WGraph, part: &[u32]) -> u64 {
    let mut cut = 0u64;
    for v in 0..g.num_vertices() {
        for &(u, w) in g.neighbors(v) {
            if part[v] != part[u as usize] {
                cut += w;
            }
        }
    }
    cut / 2
}

/// Part weights under an assignment.
pub fn part_weights(g: &WGraph, part: &[u32], k: usize) -> Vec<u64> {
    let mut w = vec![0u64; k];
    for (v, &p) in part.iter().enumerate() {
        w[p as usize] += g.vwgt[v];
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path4() -> WGraph {
        let g = Graph::from_undirected_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        WGraph::from_graph(&g)
    }

    #[test]
    fn from_graph_unit_weights() {
        let w = path4();
        assert_eq!(w.num_vertices(), 4);
        assert_eq!(w.total_vwgt(), 4);
        assert_eq!(w.neighbors(1), &[(0, 1), (2, 1)]);
    }

    #[test]
    fn contract_merges_weights() {
        let w = path4();
        // merge {0,1} -> 0, {2,3} -> 1
        let c = w.contract(&[0, 0, 1, 1], 2);
        assert_eq!(c.num_vertices(), 2);
        assert_eq!(c.vwgt, vec![2, 2]);
        // single crossing edge 1-2 survives with weight 1
        assert_eq!(c.neighbors(0), &[(1, 1)]);
        assert_eq!(edge_cut(&c, &[0, 1]), 1);
    }

    #[test]
    fn contract_accumulates_multiedges() {
        let g = Graph::from_undirected_edges(
            4,
            &[(0, 2), (0, 3), (1, 2), (1, 3)],
        );
        let w = WGraph::from_graph(&g);
        let c = w.contract(&[0, 0, 1, 1], 2);
        assert_eq!(c.neighbors(0), &[(1, 4)]);
    }

    #[test]
    fn edge_cut_and_weights() {
        let w = path4();
        let part = vec![0, 0, 1, 1];
        assert_eq!(edge_cut(&w, &part), 1);
        assert_eq!(part_weights(&w, &part, 2), vec![2, 2]);
        assert_eq!(edge_cut(&w, &[0, 1, 0, 1]), 3);
    }
}
