//! Multilevel k-way balanced graph partitioner — the in-tree METIS
//! substitute the IEP uses as its BGP solver (paper §III-C, Alg. 1 line 2).
//!
//! Pipeline: heavy-edge-matching coarsening → greedy graph-growing initial
//! partition on the coarsest graph → uncoarsening with boundary FM
//! refinement at every level.

use crate::graph::Graph;
use crate::util::rng::Rng;

use super::coarsen::coarsen;
use super::refine::{refine, RefineParams};
use super::wgraph::{edge_cut, part_weights, WGraph};

#[derive(Clone, Debug)]
pub struct PartitionResult {
    pub assignment: Vec<u32>,
    pub edge_cut: u64,
    pub part_weights: Vec<u64>,
}

#[derive(Clone, Debug)]
pub struct MultilevelParams {
    pub seed: u64,
    pub imbalance: f64,
    pub coarsen_target_per_part: usize,
    pub refine_passes: usize,
}

impl Default for MultilevelParams {
    fn default() -> Self {
        Self {
            seed: 0xF06,
            imbalance: 1.05,
            coarsen_target_per_part: 30,
            refine_passes: 8,
        }
    }
}

/// Greedy graph growing on the coarsest graph: grow each part from a BFS
/// frontier, always expanding the currently-lightest part with its most
/// connected frontier vertex.
fn initial_partition(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<u32> {
    let nv = g.num_vertices();
    let total = g.total_vwgt();
    let ideal = total as f64 / k as f64;
    let mut part = vec![u32::MAX; nv];
    let mut pw = vec![0u64; k];

    // seeds: spread via repeated BFS-farthest selection
    let mut seeds = Vec::with_capacity(k);
    let first = rng.usize_below(nv);
    seeds.push(first);
    for _ in 1..k {
        // farthest-from-seeds vertex by multi-source BFS
        let mut dist = vec![u32::MAX; nv];
        let mut q = std::collections::VecDeque::new();
        for &s in &seeds {
            dist[s] = 0;
            q.push_back(s);
        }
        while let Some(x) = q.pop_front() {
            for &(u, _) in g.neighbors(x) {
                if dist[u as usize] == u32::MAX {
                    dist[u as usize] = dist[x] + 1;
                    q.push_back(u as usize);
                }
            }
        }
        let far = (0..nv)
            .filter(|v| !seeds.contains(v))
            .max_by_key(|&v| if dist[v] == u32::MAX { u32::MAX } else { dist[v] })
            .unwrap_or_else(|| rng.usize_below(nv));
        seeds.push(far);
    }
    for (p, &s) in seeds.iter().enumerate() {
        part[s] = p as u32;
        pw[p] += g.vwgt[s];
    }

    // grow: lightest part claims its best frontier vertex
    let mut assigned = k.min(nv);
    while assigned < nv {
        let p = (0..k).min_by_key(|&p| pw[p]).unwrap();
        // best unassigned vertex adjacent to part p
        let mut best: Option<(usize, u64)> = None;
        for v in 0..nv {
            if part[v] != u32::MAX {
                continue;
            }
            let conn: u64 = g
                .neighbors(v)
                .iter()
                .filter(|&&(u, _)| part[u as usize] == p as u32)
                .map(|&(_, w)| w)
                .sum();
            if conn > 0 {
                match best {
                    Some((_, bc)) if bc >= conn => {}
                    _ => best = Some((v, conn)),
                }
            }
        }
        let v = match best {
            Some((v, _)) => v,
            None => {
                // disconnected: claim a random unassigned vertex
                (0..nv).find(|&v| part[v] == u32::MAX).unwrap()
            }
        };
        part[v] = p as u32;
        pw[p] += g.vwgt[v];
        assigned += 1;
        // stop unbounded growth of a part
        if pw[p] as f64 > ideal * 1.5 && assigned < nv {
            // temporarily mark part as full by inflating (handled by
            // lightest-part selection naturally)
        }
    }
    part
}

/// Partition `g` into `k` balanced parts minimizing edge cut.
pub fn partition(g: &Graph, k: usize, params: &MultilevelParams)
                 -> PartitionResult {
    assert!(k >= 1);
    let wg = WGraph::from_graph(g);
    if k == 1 {
        let pw = vec![wg.total_vwgt()];
        return PartitionResult {
            assignment: vec![0; g.num_vertices()],
            edge_cut: 0,
            part_weights: pw,
        };
    }
    let mut rng = Rng::new(params.seed);
    let target = (params.coarsen_target_per_part * k).max(64);
    let hier = coarsen(wg, target, params.seed ^ 0xC0A5);

    let coarsest = hier.levels.last().unwrap();
    let mut part = initial_partition(coarsest, k, &mut rng);
    let rp = RefineParams {
        max_passes: params.refine_passes,
        imbalance: params.imbalance,
    };
    refine(coarsest, &mut part, k, &rp, &mut rng);

    // project back up
    for lvl in (0..hier.cmaps.len()).rev() {
        let fine = &hier.levels[lvl];
        let cmap = &hier.cmaps[lvl];
        let mut fine_part = vec![0u32; fine.num_vertices()];
        for (v, &c) in cmap.iter().enumerate() {
            fine_part[v] = part[c as usize];
        }
        part = fine_part;
        refine(fine, &mut part, k, &rp, &mut rng);
    }

    let wg0 = &hier.levels[0];
    PartitionResult {
        edge_cut: edge_cut(wg0, &part),
        part_weights: part_weights(wg0, &part, k),
        assignment: part,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn partitions_are_balanced_and_better_than_random() {
        let (g, _) = generate::sbm(2000, 10_000, 8, 0.9, 3);
        let k = 4;
        let res = partition(&g, k, &MultilevelParams::default());
        let ideal = 2000 / k;
        for &w in &res.part_weights {
            assert!(
                (w as f64) < ideal as f64 * 1.10,
                "imbalanced: {:?}",
                res.part_weights
            );
            assert!((w as f64) > ideal as f64 * 0.80);
        }
        // random baseline cut
        let mut rng = Rng::new(4);
        let rand_assign: Vec<u32> =
            (0..2000).map(|_| rng.below(k as u64) as u32).collect();
        let wg = WGraph::from_graph(&g);
        let rand_cut = edge_cut(&wg, &rand_assign);
        assert!(
            res.edge_cut * 2 < rand_cut,
            "multilevel cut {} vs random {}",
            res.edge_cut,
            rand_cut
        );
    }

    #[test]
    fn community_structure_is_recovered() {
        // 4 well-separated communities, k=4: cut should be tiny vs total
        let (g, comm) = generate::sbm(800, 4000, 4, 0.97, 9);
        let res = partition(&g, 4, &MultilevelParams::default());
        // measure agreement: most vertices in a part share a community
        let mut agree = 0usize;
        for p in 0..4u32 {
            let mut counts = [0usize; 4];
            for v in 0..800 {
                if res.assignment[v] == p {
                    counts[comm[v] as usize] += 1;
                }
            }
            agree += counts.iter().max().unwrap();
        }
        assert!(agree > 640, "community agreement {agree}/800");
    }

    #[test]
    fn k1_is_trivial() {
        let (g, _) = generate::sbm(100, 300, 2, 0.8, 1);
        let res = partition(&g, 1, &MultilevelParams::default());
        assert_eq!(res.edge_cut, 0);
        assert!(res.assignment.iter().all(|&p| p == 0));
    }

    #[test]
    fn deterministic_for_same_seed() {
        let (g, _) = generate::sbm(500, 2000, 4, 0.9, 2);
        let a = partition(&g, 3, &MultilevelParams::default());
        let b = partition(&g, 3, &MultilevelParams::default());
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn handles_k_greater_than_components() {
        let g = crate::graph::Graph::from_undirected_edges(
            12,
            &[(0, 1), (1, 2), (3, 4), (4, 5), (6, 7), (8, 9), (10, 11)],
        );
        let res = partition(&g, 5, &MultilevelParams::default());
        let mut seen: Vec<bool> = vec![false; 5];
        for &p in &res.assignment {
            seen[p as usize] = true;
        }
        assert!(seen.iter().filter(|&&b| b).count() >= 4);
    }
}
