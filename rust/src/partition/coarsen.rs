//! Coarsening phase of the multilevel partitioner: heavy-edge matching
//! (METIS' HEM) followed by contraction.

use crate::util::rng::Rng;

use super::wgraph::WGraph;

/// One heavy-edge matching pass. Returns (cmap, coarse_n): matched pairs
/// share a coarse id, unmatched vertices keep their own.
pub fn heavy_edge_matching(g: &WGraph, rng: &mut Rng) -> (Vec<u32>, usize) {
    let nv = g.num_vertices();
    let mut order: Vec<u32> = (0..nv as u32).collect();
    rng.shuffle(&mut order);
    // visit light vertices first — standard HEM heuristic keeps weights even
    order.sort_by_key(|&v| g.vwgt[v as usize]);

    let mut mate = vec![u32::MAX; nv];
    for &v in &order {
        let v = v as usize;
        if mate[v] != u32::MAX {
            continue;
        }
        let mut best: Option<(u32, u64)> = None;
        for &(u, w) in g.neighbors(v) {
            if mate[u as usize] == u32::MAX && u as usize != v {
                match best {
                    Some((_, bw)) if bw >= w => {}
                    _ => best = Some((u, w)),
                }
            }
        }
        match best {
            Some((u, _)) => {
                mate[v] = u;
                mate[u as usize] = v as u32;
            }
            None => mate[v] = v as u32,
        }
    }
    // assign dense coarse ids
    let mut cmap = vec![u32::MAX; nv];
    let mut next = 0u32;
    for v in 0..nv {
        if cmap[v] != u32::MAX {
            continue;
        }
        let m = mate[v] as usize;
        cmap[v] = next;
        cmap[m] = next; // m == v for unmatched
        next += 1;
    }
    (cmap, next as usize)
}

/// Coarsen until `target_nv` or until progress stalls. Returns the level
/// stack: `levels[0]` is the input graph; `cmaps[i]` maps level i -> i+1.
pub struct Hierarchy {
    pub levels: Vec<WGraph>,
    pub cmaps: Vec<Vec<u32>>,
}

pub fn coarsen(g: WGraph, target_nv: usize, seed: u64) -> Hierarchy {
    let mut rng = Rng::new(seed);
    let mut levels = vec![g];
    let mut cmaps = Vec::new();
    loop {
        let cur = levels.last().unwrap();
        let nv = cur.num_vertices();
        if nv <= target_nv {
            break;
        }
        let (cmap, cn) = heavy_edge_matching(cur, &mut rng);
        if cn as f64 > nv as f64 * 0.95 {
            break; // stalled (e.g. star graphs)
        }
        let coarse = cur.contract(&cmap, cn);
        cmaps.push(cmap);
        levels.push(coarse);
    }
    Hierarchy { levels, cmaps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn ring(n: usize) -> WGraph {
        let edges: Vec<(u32, u32)> = (0..n)
            .map(|i| (i as u32, ((i + 1) % n) as u32))
            .collect();
        WGraph::from_graph(&Graph::from_undirected_edges(n, &edges))
    }

    #[test]
    fn matching_is_valid() {
        let g = ring(100);
        let mut rng = Rng::new(1);
        let (cmap, cn) = heavy_edge_matching(&g, &mut rng);
        assert!(cn < 100 && cn >= 50);
        // every coarse id has 1 or 2 members
        let mut count = vec![0; cn];
        for &c in &cmap {
            count[c as usize] += 1;
        }
        assert!(count.iter().all(|&c| c == 1 || c == 2));
        // matched pairs are adjacent
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); cn];
        for (v, &c) in cmap.iter().enumerate() {
            members[c as usize].push(v);
        }
        for m in members.iter().filter(|m| m.len() == 2) {
            assert!(g.neighbors(m[0]).iter().any(|&(u, _)| u as usize == m[1]));
        }
    }

    #[test]
    fn coarsen_preserves_total_weight() {
        let g = ring(256);
        let total = g.total_vwgt();
        let h = coarsen(g, 16, 7);
        assert!(h.levels.len() > 2);
        for lvl in &h.levels {
            assert_eq!(lvl.total_vwgt(), total);
        }
        assert!(h.levels.last().unwrap().num_vertices() <= 32);
    }

    #[test]
    fn coarsen_handles_disconnected_isolates() {
        let g = WGraph::from_graph(&Graph::from_undirected_edges(
            10,
            &[(0, 1), (2, 3)],
        ));
        let h = coarsen(g, 2, 3);
        assert!(h.levels.last().unwrap().num_vertices() >= 6 - 2);
    }
}
