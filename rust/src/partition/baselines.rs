//! Baseline partitioners for ablations and the motivation experiments:
//! random equal split (the §II-C measurement setup) and contiguous range
//! (linear) split.

use crate::graph::Graph;
use crate::util::rng::Rng;

/// Random balanced split: shuffles vertices and deals them round-robin —
//  exactly the "randomly divided into equal parts" setup of §II-C.
pub fn random_split(g: &Graph, k: usize, seed: u64) -> Vec<u32> {
    let nv = g.num_vertices();
    let mut order: Vec<u32> = (0..nv as u32).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut order);
    let mut part = vec![0u32; nv];
    for (i, &v) in order.iter().enumerate() {
        part[v as usize] = (i % k) as u32;
    }
    part
}

/// Contiguous ranges 0..n/k, n/k..2n/k, ... (cheap, locality only if the
/// vertex numbering is already spatial).
pub fn linear_split(g: &Graph, k: usize) -> Vec<u32> {
    let nv = g.num_vertices();
    (0..nv).map(|v| ((v * k) / nv).min(k - 1) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn random_split_is_balanced() {
        let (g, _) = generate::sbm(1000, 3000, 4, 0.9, 1);
        let part = random_split(&g, 7, 3);
        let mut counts = vec![0usize; 7];
        for &p in &part {
            counts[p as usize] += 1;
        }
        let (lo, hi) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(hi - lo <= 1, "{counts:?}");
    }

    #[test]
    fn linear_split_is_contiguous_and_balanced() {
        let (g, _) = generate::sbm(1003, 3000, 4, 0.9, 2);
        let part = linear_split(&g, 4);
        let mut counts = vec![0usize; 4];
        for &p in &part {
            counts[p as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c >= 250 && c <= 252), "{counts:?}");
        // contiguity: non-decreasing
        assert!(part.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn random_split_deterministic_per_seed() {
        let (g, _) = generate::sbm(100, 300, 2, 0.8, 5);
        assert_eq!(random_split(&g, 3, 42), random_split(&g, 3, 42));
        assert_ne!(random_split(&g, 3, 42), random_split(&g, 3, 43));
    }
}
