//! Boundary refinement: greedy k-way FM-style passes. Moves boundary
//! vertices to the adjacent part with the highest edge-cut gain subject to
//! the balance constraint.

use crate::util::rng::Rng;

use super::wgraph::WGraph;

pub struct RefineParams {
    pub max_passes: usize,
    pub imbalance: f64, // max part weight = imbalance * ideal
}

impl Default for RefineParams {
    fn default() -> Self {
        Self { max_passes: 8, imbalance: 1.05 }
    }
}

/// In-place refinement. Returns total gain (cut reduction).
pub fn refine(
    g: &WGraph,
    part: &mut [u32],
    k: usize,
    params: &RefineParams,
    rng: &mut Rng,
) -> u64 {
    let nv = g.num_vertices();
    let ideal = g.total_vwgt() as f64 / k as f64;
    let max_w = (ideal * params.imbalance).ceil() as u64;
    let mut pw = super::wgraph::part_weights(g, part, k);
    let mut total_gain = 0u64;
    let mut conn = vec![0u64; k]; // scratch: connectivity of v to each part

    for _pass in 0..params.max_passes {
        let mut order: Vec<u32> = (0..nv as u32).collect();
        rng.shuffle(&mut order);
        let mut moved = 0usize;
        for &v in &order {
            let v = v as usize;
            let home = part[v] as usize;
            // compute connectivity to adjacent parts
            let mut touched: Vec<usize> = Vec::with_capacity(4);
            for &(u, w) in g.neighbors(v) {
                let p = part[u as usize] as usize;
                if conn[p] == 0 {
                    touched.push(p);
                }
                conn[p] += w;
            }
            let internal = conn[home];
            let mut best: Option<(usize, u64)> = None;
            for &p in &touched {
                if p == home {
                    continue;
                }
                if pw[p] + g.vwgt[v] > max_w {
                    continue;
                }
                if conn[p] > internal {
                    let gain = conn[p] - internal;
                    match best {
                        Some((_, bg)) if bg >= gain => {}
                        _ => best = Some((p, gain)),
                    }
                }
            }
            // also allow pure balance moves out of overweight parts
            if best.is_none() && pw[home] > max_w {
                for &p in &touched {
                    if p != home && pw[p] + g.vwgt[v] <= max_w
                        && conn[p] == internal
                    {
                        best = Some((p, 0));
                        break;
                    }
                }
            }
            if let Some((p, gain)) = best {
                if pw[home] > g.vwgt[v] {
                    pw[home] -= g.vwgt[v];
                    pw[p] += g.vwgt[v];
                    part[v] = p as u32;
                    total_gain += gain;
                    moved += 1;
                }
            }
            for &p in &touched {
                conn[p] = 0;
            }
        }
        if moved == 0 {
            break;
        }
    }
    total_gain
}

/// Parameters for the delta-aware boundary pass. The balance cap uses
/// unit live-vertex weights, mirroring the churn engine's notion of
/// load (the rescheduler handles compute skew separately).
pub struct BoundaryParams {
    pub imbalance: f64,
}

impl Default for BoundaryParams {
    fn default() -> Self {
        Self { imbalance: 1.05 }
    }
}

/// Delta-aware boundary refinement: a single deterministic,
/// ascending-id pass over an explicit `candidates` list (the vertices
/// a topology delta just touched) that migrates a candidate to the
/// adjacent part with the strictly highest edge-cut gain — but ONLY
/// between parts flagged `dirty`, so a move never invalidates a
/// partition the churn round would otherwise preserve. This replaces a
/// from-scratch multilevel repartition: cost is O(Σ deg(candidates)),
/// not O(V+E).
///
/// `neighbors(v, buf)` fills `buf` with v's current live neighbors;
/// `assignment` is updated in place; the applied moves `(v, from, to)`
/// are returned so the caller can maintain its own per-part state.
/// No RNG: for a fixed delta batch the result is bit-deterministic.
pub fn refine_boundary<N: FnMut(u32, &mut Vec<u32>)>(
    n_vertices: usize,
    mut neighbors: N,
    alive: &[bool],
    assignment: &mut [u32],
    n_parts: usize,
    candidates: &[u32],
    dirty: &[bool],
    params: &BoundaryParams,
) -> Vec<(u32, u32, u32)> {
    debug_assert_eq!(alive.len(), n_vertices);
    debug_assert_eq!(assignment.len(), n_vertices);
    let live_total =
        alive.iter().filter(|&&a| a).count() as f64;
    let max_w =
        ((live_total / n_parts as f64) * params.imbalance).ceil()
            as usize;
    let mut pw = vec![0usize; n_parts];
    for v in 0..n_vertices {
        if alive[v] {
            pw[assignment[v] as usize] += 1;
        }
    }
    let mut conn = vec![0usize; n_parts];
    let mut touched: Vec<usize> = Vec::with_capacity(8);
    let mut nbuf: Vec<u32> = Vec::new();
    let mut moves = Vec::new();
    for &v in candidates {
        let vi = v as usize;
        if !alive[vi] || !dirty[assignment[vi] as usize] {
            continue;
        }
        let home = assignment[vi] as usize;
        neighbors(v, &mut nbuf);
        for &u in &nbuf {
            let p = assignment[u as usize] as usize;
            if conn[p] == 0 {
                touched.push(p);
            }
            conn[p] += 1;
        }
        let internal = conn[home];
        let mut best: Option<(usize, usize)> = None;
        for &p in &touched {
            if p == home || !dirty[p] || pw[p] + 1 > max_w {
                continue;
            }
            if conn[p] > internal {
                let gain = conn[p] - internal;
                // strictly better gain wins; ties keep the lowest
                // part id (touched order is not deterministic enough)
                match best {
                    Some((bp, bg))
                        if bg > gain || (bg == gain && bp < p) => {}
                    _ => best = Some((p, gain)),
                }
            }
        }
        if let Some((p, _)) = best {
            if pw[home] > 1 {
                pw[home] -= 1;
                pw[p] += 1;
                assignment[vi] = p as u32;
                moves.push((v, home as u32, p as u32));
            }
        }
        for &p in &touched {
            conn[p] = 0;
        }
        touched.clear();
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::partition::wgraph::{edge_cut, part_weights, WGraph};

    /// Two 20-cliques joined by one edge; a scrambled assignment must
    /// refine to (nearly) the natural 2-cut.
    #[test]
    fn refine_recovers_clique_split() {
        let mut edges = Vec::new();
        for a in 0..20u32 {
            for b in a + 1..20 {
                edges.push((a, b));
                edges.push((a + 20, b + 20));
            }
        }
        edges.push((0, 20));
        let g = WGraph::from_graph(&Graph::from_undirected_edges(40, &edges));
        // scrambled but balanced start
        let mut part: Vec<u32> = (0..40).map(|v| (v % 2) as u32).collect();
        let before = edge_cut(&g, &part);
        let mut rng = Rng::new(5);
        refine(&g, &mut part, 2, &RefineParams::default(), &mut rng);
        let after = edge_cut(&g, &part);
        assert!(after < before / 4, "cut {before} -> {after}");
        let pw = part_weights(&g, &part, 2);
        assert!(pw.iter().all(|&w| w >= 18 && w <= 22), "{pw:?}");
    }

    #[test]
    fn refine_respects_balance_cap() {
        // star: center + 30 leaves; cut-optimal would put everything in
        // one part, balance must forbid it.
        let edges: Vec<(u32, u32)> = (1..31).map(|i| (0u32, i)).collect();
        let g = WGraph::from_graph(&Graph::from_undirected_edges(31, &edges));
        let mut part: Vec<u32> = (0..31).map(|v| (v % 2) as u32).collect();
        let mut rng = Rng::new(6);
        refine(&g, &mut part, 2,
               &RefineParams { max_passes: 10, imbalance: 1.10 }, &mut rng);
        let pw = part_weights(&g, &part, 2);
        let max_allowed = (31.0f64 / 2.0 * 1.10).ceil() as u64;
        assert!(pw.iter().all(|&w| w <= max_allowed), "{pw:?}");
        assert!(pw.iter().all(|&w| w > 0));
    }

    fn adj(g: &Graph) -> impl FnMut(u32, &mut Vec<u32>) + '_ {
        |v, buf| {
            buf.clear();
            buf.extend_from_slice(g.neighbors(v as usize));
        }
    }

    /// A vertex sitting in the wrong clique hops home; a vertex whose
    /// home part is clean stays put even with positive gain.
    #[test]
    fn boundary_pass_moves_only_dirty_candidates() {
        let mut edges = Vec::new();
        for a in 0..6u32 {
            for b in a + 1..6 {
                edges.push((a, b));
                edges.push((a + 6, b + 6));
            }
        }
        edges.push((0, 6));
        let g = Graph::from_undirected_edges(12, &edges);
        let alive = vec![true; 12];
        // vertex 5 misplaced into part 1, vertex 11 into part 0
        let mut asn: Vec<u32> =
            (0..12).map(|v| (v >= 6) as u32).collect();
        asn[5] = 1;
        asn[11] = 0;
        let moves = refine_boundary(
            12, adj(&g), &alive, &mut asn, 2,
            &[5, 11], &[true, true],
            &BoundaryParams { imbalance: 1.5 },
        );
        assert_eq!(moves, vec![(5, 1, 0), (11, 0, 1)]);
        assert_eq!(asn[5], 0);
        assert_eq!(asn[11], 1);

        // same start, but part 0 is clean: 5 must not move (its home
        // part 1 is dirty but the only profitable target is clean)
        let mut asn2: Vec<u32> =
            (0..12).map(|v| (v >= 6) as u32).collect();
        asn2[5] = 1;
        let moves2 = refine_boundary(
            12, adj(&g), &alive, &mut asn2, 2,
            &[5], &[false, true],
            &BoundaryParams { imbalance: 1.5 },
        );
        assert!(moves2.is_empty());
        assert_eq!(asn2[5], 1);
    }

    /// The balance cap blocks gain moves that would overload a part,
    /// dead vertices are skipped, and the pass reduces the cut.
    #[test]
    fn boundary_pass_respects_balance_and_liveness() {
        let edges: Vec<(u32, u32)> =
            (1..8).map(|i| (0u32, i)).collect();
        let g = Graph::from_undirected_edges(8, &edges);
        let mut alive = vec![true; 8];
        alive[7] = false;
        let mut asn: Vec<u32> = (0..8).map(|v| (v % 2) as u32).collect();
        // leaves all want to join the hub's part 0; cap forbids most
        let moves = refine_boundary(
            8, adj(&g), &alive, &mut asn, 2,
            &[1, 3, 5, 7], &[true, true],
            &BoundaryParams { imbalance: 1.2 },
        );
        let max_w = ((7.0 / 2.0) * 1.2_f64).ceil() as usize;
        let p0 = (0..8).filter(|&v| alive[v] && asn[v] == 0).count();
        assert!(p0 <= max_w, "part 0 has {p0} > cap {max_w}");
        assert!(moves.iter().all(|&(v, _, _)| v != 7), "dead moved");
        let wg = WGraph::from_graph(&g);
        assert!(edge_cut(&wg, &asn) < 7, "no cut improvement");
    }
}
