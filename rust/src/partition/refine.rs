//! Boundary refinement: greedy k-way FM-style passes. Moves boundary
//! vertices to the adjacent part with the highest edge-cut gain subject to
//! the balance constraint.

use crate::util::rng::Rng;

use super::wgraph::WGraph;

pub struct RefineParams {
    pub max_passes: usize,
    pub imbalance: f64, // max part weight = imbalance * ideal
}

impl Default for RefineParams {
    fn default() -> Self {
        Self { max_passes: 8, imbalance: 1.05 }
    }
}

/// In-place refinement. Returns total gain (cut reduction).
pub fn refine(
    g: &WGraph,
    part: &mut [u32],
    k: usize,
    params: &RefineParams,
    rng: &mut Rng,
) -> u64 {
    let nv = g.num_vertices();
    let ideal = g.total_vwgt() as f64 / k as f64;
    let max_w = (ideal * params.imbalance).ceil() as u64;
    let mut pw = super::wgraph::part_weights(g, part, k);
    let mut total_gain = 0u64;
    let mut conn = vec![0u64; k]; // scratch: connectivity of v to each part

    for _pass in 0..params.max_passes {
        let mut order: Vec<u32> = (0..nv as u32).collect();
        rng.shuffle(&mut order);
        let mut moved = 0usize;
        for &v in &order {
            let v = v as usize;
            let home = part[v] as usize;
            // compute connectivity to adjacent parts
            let mut touched: Vec<usize> = Vec::with_capacity(4);
            for &(u, w) in g.neighbors(v) {
                let p = part[u as usize] as usize;
                if conn[p] == 0 {
                    touched.push(p);
                }
                conn[p] += w;
            }
            let internal = conn[home];
            let mut best: Option<(usize, u64)> = None;
            for &p in &touched {
                if p == home {
                    continue;
                }
                if pw[p] + g.vwgt[v] > max_w {
                    continue;
                }
                if conn[p] > internal {
                    let gain = conn[p] - internal;
                    match best {
                        Some((_, bg)) if bg >= gain => {}
                        _ => best = Some((p, gain)),
                    }
                }
            }
            // also allow pure balance moves out of overweight parts
            if best.is_none() && pw[home] > max_w {
                for &p in &touched {
                    if p != home && pw[p] + g.vwgt[v] <= max_w
                        && conn[p] == internal
                    {
                        best = Some((p, 0));
                        break;
                    }
                }
            }
            if let Some((p, gain)) = best {
                if pw[home] > g.vwgt[v] {
                    pw[home] -= g.vwgt[v];
                    pw[p] += g.vwgt[v];
                    part[v] = p as u32;
                    total_gain += gain;
                    moved += 1;
                }
            }
            for &p in &touched {
                conn[p] = 0;
            }
        }
        if moved == 0 {
            break;
        }
    }
    total_gain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::partition::wgraph::{edge_cut, part_weights, WGraph};

    /// Two 20-cliques joined by one edge; a scrambled assignment must
    /// refine to (nearly) the natural 2-cut.
    #[test]
    fn refine_recovers_clique_split() {
        let mut edges = Vec::new();
        for a in 0..20u32 {
            for b in a + 1..20 {
                edges.push((a, b));
                edges.push((a + 20, b + 20));
            }
        }
        edges.push((0, 20));
        let g = WGraph::from_graph(&Graph::from_undirected_edges(40, &edges));
        // scrambled but balanced start
        let mut part: Vec<u32> = (0..40).map(|v| (v % 2) as u32).collect();
        let before = edge_cut(&g, &part);
        let mut rng = Rng::new(5);
        refine(&g, &mut part, 2, &RefineParams::default(), &mut rng);
        let after = edge_cut(&g, &part);
        assert!(after < before / 4, "cut {before} -> {after}");
        let pw = part_weights(&g, &part, 2);
        assert!(pw.iter().all(|&w| w >= 18 && w <= 22), "{pw:?}");
    }

    #[test]
    fn refine_respects_balance_cap() {
        // star: center + 30 leaves; cut-optimal would put everything in
        // one part, balance must forbid it.
        let edges: Vec<(u32, u32)> = (1..31).map(|i| (0u32, i)).collect();
        let g = WGraph::from_graph(&Graph::from_undirected_edges(31, &edges));
        let mut part: Vec<u32> = (0..31).map(|v| (v % 2) as u32).collect();
        let mut rng = Rng::new(6);
        refine(&g, &mut part, 2,
               &RefineParams { max_passes: 10, imbalance: 1.10 }, &mut rng);
        let pw = part_weights(&g, &part, 2);
        let max_allowed = (31.0f64 / 2.0 * 1.10).ceil() as u64;
        assert!(pw.iter().all(|&w| w <= max_allowed), "{pw:?}");
        assert!(pw.iter().all(|&w| w > 0));
    }
}
