//! Balanced graph partitioning (BGP) substrate — the solver family the
//! IEP's first step relies on (paper §III-C / Alg. 1). The default is the
//! in-tree multilevel partitioner (METIS substitute); baselines exist for
//! the §II-C motivation setup and ablations.

pub mod baselines;
pub mod coarsen;
pub mod multilevel;
pub mod refine;
pub mod wgraph;

pub use multilevel::{partition, MultilevelParams, PartitionResult};
