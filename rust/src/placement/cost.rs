//! The IEP cost model — Eqs. (5), (6), (8) of the paper:
//!
//!   t_colle(j)   = Σ_i x_ij · φ / b_j
//!   t_exec(j)    = ω_j(∪_i x_ij v_i) + K·δ
//!   ⟨P_k, f_j⟩   = |P_k|·φ/b_j + ω_j(P_k) + K·δ
//!
//! φ is the per-vertex wire size (post-CO when compression is enabled),
//! b_j the fog's collection bandwidth, ω_j its fitted latency model, and
//! δ the per-layer BSP synchronization cost.

use crate::fog::{Cluster, FogNode};
use crate::net::{self, NetProfile};
use crate::profile::{Cardinality, PerfModel};

/// Statistics of one data partition, from the halo-extracted subgraph.
#[derive(Clone, Copy, Debug)]
pub struct PartStats {
    pub n_vertices: usize,
    /// One-hop neighbor multiset size (local edge count) — the |N_V| axis.
    pub n_edges: usize,
    /// Halo vertices pulled from other fogs each sync round.
    pub n_halo: usize,
}

impl PartStats {
    pub fn cardinality(&self) -> Cardinality {
        Cardinality::new(self.n_vertices, self.n_edges)
    }
}

/// Everything Eq. (8) needs beyond the partition itself.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Wire bytes per vertex (φ) — compressed when the CO is active.
    pub phi_bytes: f64,
    /// GNN depth K.
    pub k_layers: usize,
    /// Activation row bytes exchanged at sync (hidden dim × 4).
    pub sync_row_bytes: f64,
    /// Devices sharing each fog's access point (contention input).
    pub devices_per_fog: usize,
    pub net: NetProfile,
}

impl CostModel {
    /// Collection time of a partition on fog j — Eq. (5), with the
    /// node's heterogeneous bandwidth share b_j.
    pub fn t_colle(&self, part: &PartStats, fog: &FogNode) -> f64 {
        let b = net::fog_uplink_mbps(&self.net, self.devices_per_fog)
            * fog.node_type.bandwidth_share();
        net::transfer_time_s(
            (part.n_vertices as f64 * self.phi_bytes) as usize,
            b,
            self.net.lan_rtt_s,
        )
    }

    /// Per-round synchronization cost δ for a partition: halo activations
    /// over the inter-fog LAN.
    pub fn delta(&self, part: &PartStats) -> f64 {
        net::transfer_time_s(
            (part.n_halo as f64 * self.sync_row_bytes) as usize,
            self.net.interfog_mbps,
            self.net.interfog_rtt_s,
        )
    }

    /// Execution time of a partition on fog j — Eq. (6).
    pub fn t_exec(&self, part: &PartStats, fog: &FogNode,
                  omega: &PerfModel) -> f64 {
        let base = omega.predict(part.cardinality());
        fog.scale_time(base) + self.k_layers as f64 * self.delta(part)
    }

    /// Composite pair cost ⟨P_k, f_j⟩ — Eq. (8).
    pub fn pair_cost(&self, part: &PartStats, fog: &FogNode,
                     omega: &PerfModel) -> f64 {
        self.t_colle(part, fog) + self.t_exec(part, fog, omega)
    }

    /// Full n×n weight matrix for the partition→fog bipartite graph.
    pub fn weight_matrix(&self, parts: &[PartStats], cluster: &Cluster,
                         omegas: &[PerfModel]) -> Vec<Vec<f64>> {
        assert_eq!(cluster.len(), omegas.len());
        parts
            .iter()
            .map(|p| {
                cluster
                    .nodes
                    .iter()
                    .zip(omegas)
                    .map(|(f, m)| self.pair_cost(p, f, m))
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fog::{Cluster, NodeType};
    use crate::net::{NetKind, NetProfile};

    fn cm() -> CostModel {
        CostModel {
            phi_bytes: 52.0 * 8.0,
            k_layers: 2,
            sync_row_bytes: 64.0 * 4.0,
            devices_per_fog: 2,
            net: NetProfile::get(NetKind::Wifi),
        }
    }

    fn part(v: usize, e: usize, h: usize) -> PartStats {
        PartStats { n_vertices: v, n_edges: e, n_halo: h }
    }

    fn omega() -> PerfModel {
        PerfModel { beta_v: 2e-6, beta_n: 3e-7, intercept: 1e-3, r2: 1.0 }
    }

    #[test]
    fn weaker_fog_costs_more() {
        let m = cm();
        let p = part(2000, 15_000, 300);
        let a = FogNode::new(0, NodeType::A);
        let c = FogNode::new(1, NodeType::C);
        let o = omega();
        assert!(m.pair_cost(&p, &a, &o) > m.pair_cost(&p, &c, &o));
        // heterogeneous b_j: the weak node also collects slower
        assert!(m.t_colle(&p, &a) > m.t_colle(&p, &c));
    }

    #[test]
    fn bigger_partition_costs_more_everywhere() {
        let m = cm();
        let small = part(500, 3000, 100);
        let big = part(5000, 40_000, 600);
        let f = FogNode::new(0, NodeType::B);
        let o = omega();
        assert!(m.pair_cost(&big, &f, &o) > m.pair_cost(&small, &f, &o));
        assert!(m.t_colle(&big, &f) > m.t_colle(&small, &f));
        assert!(m.delta(&big) > m.delta(&small));
    }

    #[test]
    fn sync_cost_scales_with_layers() {
        let mut m = cm();
        let p = part(1000, 8000, 400);
        let f = FogNode::new(0, NodeType::B);
        let o = omega();
        let t2 = m.t_exec(&p, &f, &o);
        m.k_layers = 4;
        let t4 = m.t_exec(&p, &f, &o);
        assert!((t4 - t2 - 2.0 * m.delta(&p)).abs() < 1e-12);
    }

    #[test]
    fn matrix_shape_and_content() {
        let m = cm();
        let parts = vec![part(100, 700, 10), part(150, 900, 20)];
        let cluster = Cluster::new(&[NodeType::A, NodeType::B],
                                   NetKind::Wifi);
        let omegas = vec![omega(), omega()];
        let w = m.weight_matrix(&parts, &cluster, &omegas);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].len(), 2);
        assert!(w[0][0] > w[0][1]); // A costs more than B
    }
}
