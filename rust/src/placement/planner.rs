//! The Inference Execution Planner (IEP) — paper §III-C, Algorithm 1 —
//! plus the two straw-man mapping strategies it is evaluated against in
//! Fig. 8 (METIS+Random, METIS+Greedy).
//!
//! Step 1: balanced min-cut partitioning (multilevel BGP).
//! Step 2: resource-aware partition→fog mapping solved as an LBAP
//!         (threshold + Hungarian feasibility, binary-searched).

use crate::fog::Cluster;
use crate::graph::{subgraph, Graph};
use crate::partition::{multilevel, MultilevelParams};
use crate::profile::PerfModel;
use crate::util::rng::Rng;

use super::cost::{CostModel, PartStats};
use super::lbap;

/// Partition→fog mapping strategy (IEP step 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingStrategy {
    /// Fograph's LBAP min-max mapping.
    Lbap,
    /// Straw-man: arbitrary (seeded random) assignment — the placement of
    /// DistDGL-style distributed processing the paper compares against.
    Random(u64),
    /// Straw-man: greedy min-pair-cost assignment.
    Greedy,
}

/// A complete data placement π plus its predicted costs.
#[derive(Clone, Debug)]
pub struct Plan {
    /// vertex → fog id.
    pub assignment: Vec<u32>,
    /// partition index → fog id.
    pub part_to_fog: Vec<usize>,
    /// Per-partition stats, in partition order.
    pub parts: Vec<PartStats>,
    /// Pair-cost matrix (partition × fog) under the cost model.
    pub weights: Vec<Vec<f64>>,
    /// Predicted bottleneck (max pair cost of the chosen mapping).
    pub bottleneck: f64,
    /// Edge cut of the partitioning step.
    pub edge_cut: u64,
}

/// Compute partition statistics via halo extraction. Drives the
/// streamed grounding path directly: each sub-CSR is dropped as soon
/// as its three counters are read, so planning never holds more than
/// one partition's sub-CSR — at million-vertex scale the planner would
/// otherwise materialize the full grounding just to size partitions.
pub fn partition_stats(g: &Graph, assignment: &[u32], n: usize)
                       -> Vec<PartStats> {
    let mut stream = subgraph::GroundingStream::new(g, assignment, n);
    let mut parts = Vec::with_capacity(n);
    while let Some(s) = stream.next_fog() {
        parts.push(PartStats {
            n_vertices: s.n_local,
            n_edges: s.num_edges(),
            n_halo: s.n_halo(),
        });
    }
    parts
}

/// Run the full IEP: BGP partitioning + the chosen mapping strategy.
pub fn plan(
    g: &Graph,
    cluster: &Cluster,
    omegas: &[PerfModel],
    cost: &CostModel,
    strategy: MappingStrategy,
    bgp_params: &MultilevelParams,
) -> Plan {
    let n = cluster.len();
    assert_eq!(omegas.len(), n);
    // ---- step 1: balanced min-cut partitions ------------------------------
    let part_res = multilevel::partition(g, n, bgp_params);
    let parts = partition_stats(g, &part_res.assignment, n);
    // ---- step 2: partition→fog mapping ------------------------------------
    let weights = cost.weight_matrix(&parts, cluster, omegas);
    let part_to_fog: Vec<usize> = match strategy {
        MappingStrategy::Lbap => lbap::solve(&weights).0,
        MappingStrategy::Random(seed) => {
            let mut fogs: Vec<usize> = (0..n).collect();
            Rng::new(seed).shuffle(&mut fogs);
            fogs
        }
        MappingStrategy::Greedy => greedy_mapping(&weights),
    };
    let bottleneck = lbap::bottleneck(&weights, &part_to_fog);
    // vertex → fog
    let assignment: Vec<u32> = part_res
        .assignment
        .iter()
        .map(|&p| part_to_fog[p as usize] as u32)
        .collect();
    Plan {
        assignment,
        part_to_fog,
        parts,
        weights,
        bottleneck,
        edge_cut: part_res.edge_cut,
    }
}

/// Greedy: visit partitions in descending size, give each the free fog
/// with the lowest pair cost.
fn greedy_mapping(weights: &[Vec<f64>]) -> Vec<usize> {
    let n = weights.len();
    let mut order: Vec<usize> = (0..n).collect();
    // heaviest row (by min cost) first so big partitions get first pick
    order.sort_by(|&a, &b| {
        let ma = weights[a].iter().cloned().fold(f64::INFINITY, f64::min);
        let mb = weights[b].iter().cloned().fold(f64::INFINITY, f64::min);
        mb.partial_cmp(&ma).unwrap()
    });
    let mut used = vec![false; n];
    let mut out = vec![0usize; n];
    for &k in &order {
        let j = (0..n)
            .filter(|&j| !used[j])
            .min_by(|&a, &b| {
                weights[k][a].partial_cmp(&weights[k][b]).unwrap()
            })
            .unwrap();
        used[j] = true;
        out[k] = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fog::NodeType;
    use crate::net::{NetKind, NetProfile};
    use crate::graph::generate;

    fn setup() -> (Graph, Cluster, Vec<PerfModel>, CostModel) {
        let (g, _) = generate::sbm(3000, 15_000, 12, 0.9, 7);
        let cluster = Cluster::new(
            &[NodeType::A, NodeType::B, NodeType::B, NodeType::C],
            NetKind::Wifi,
        );
        let omega = PerfModel {
            beta_v: 2e-6,
            beta_n: 3e-7,
            intercept: 1e-3,
            r2: 1.0,
        };
        let omegas = vec![omega; 4];
        let cost = CostModel {
            phi_bytes: 52.0 * 8.0,
            k_layers: 2,
            sync_row_bytes: 256.0,
            devices_per_fog: 2,
            net: NetProfile::get(NetKind::Wifi),
        };
        (g, cluster, omegas, cost)
    }

    #[test]
    fn lbap_plan_beats_random_and_greedy_is_between() {
        let (g, cluster, omegas, cost) = setup();
        let p = &MultilevelParams::default();
        let lbap_plan = plan(&g, &cluster, &omegas, &cost,
                             MappingStrategy::Lbap, p);
        let greedy_plan = plan(&g, &cluster, &omegas, &cost,
                               MappingStrategy::Greedy, p);
        // random averaged over seeds
        let mut rand_bn = 0.0;
        for s in 0..5 {
            rand_bn += plan(&g, &cluster, &omegas, &cost,
                            MappingStrategy::Random(s), p)
                .bottleneck;
        }
        rand_bn /= 5.0;
        assert!(lbap_plan.bottleneck <= greedy_plan.bottleneck + 1e-12);
        assert!(lbap_plan.bottleneck < rand_bn);
    }

    #[test]
    fn plan_is_a_valid_placement() {
        let (g, cluster, omegas, cost) = setup();
        let p = plan(&g, &cluster, &omegas, &cost, MappingStrategy::Lbap,
                     &MultilevelParams::default());
        assert_eq!(p.assignment.len(), g.num_vertices());
        assert!(p.assignment.iter().all(|&f| (f as usize) < cluster.len()));
        // every fog serves exactly one partition
        let mut seen = vec![false; cluster.len()];
        for &f in &p.part_to_fog {
            assert!(!seen[f], "fog {f} assigned twice");
            seen[f] = true;
        }
        // partition stats are populated
        let total: usize = p.parts.iter().map(|s| s.n_vertices).sum();
        assert_eq!(total, g.num_vertices());
    }

    #[test]
    fn powerful_fog_gets_bigger_partition() {
        // strongly heterogeneous: C should carry more vertices than A
        let (g, cluster, omegas, cost) = setup();
        let p = plan(&g, &cluster, &omegas, &cost, MappingStrategy::Lbap,
                     &MultilevelParams::default());
        // identify A and C fogs
        let a_id = cluster.nodes.iter()
            .position(|n| n.node_type == NodeType::A).unwrap() as u32;
        let c_id = cluster.nodes.iter()
            .position(|n| n.node_type == NodeType::C).unwrap() as u32;
        let count = |fid: u32| {
            p.assignment.iter().filter(|&&f| f == fid).count()
        };
        // balanced BGP makes sizes near-equal; LBAP at least must not give
        // A more than C when exec dominates collection
        assert!(count(a_id) <= count(c_id) + g.num_vertices() / 10,
                "A={} C={}", count(a_id), count(c_id));
    }

    #[test]
    fn greedy_mapping_uses_each_fog_once() {
        let w = vec![
            vec![1.0, 2.0, 3.0],
            vec![2.0, 1.0, 3.0],
            vec![3.0, 2.0, 1.0],
        ];
        let m = greedy_mapping(&w);
        let mut s = m.clone();
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 2]);
    }
}
