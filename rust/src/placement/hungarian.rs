//! Assignment-problem substrate:
//! * `min_cost_assignment` — the Hungarian algorithm (Jonker–Volgenant
//!   potentials form, O(n³)) minimizing the SUM of costs — the
//!   "traditional bipartite matching" the paper contrasts with (§III-C).
//! * `max_bipartite_matching` — Kuhn's augmenting-path matching, the
//!   perfect-matching feasibility test inside the LBAP threshold loop
//!   (Alg. 1 line 11).

/// Minimum-cost perfect assignment on a square cost matrix.
/// Returns (assignment row->col, total cost).
pub fn min_cost_assignment(cost: &[Vec<f64>]) -> (Vec<usize>, f64) {
    let n = cost.len();
    assert!(cost.iter().all(|r| r.len() == n), "square matrix required");
    const INF: f64 = f64::INFINITY;
    // potentials; 1-indexed internal arrays (classic JV formulation)
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[col] = row matched to col (0 = none)
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut assign = vec![0usize; n];
    let mut total = 0.0;
    for j in 1..=n {
        if p[j] > 0 {
            assign[p[j] - 1] = j - 1;
            total += cost[p[j] - 1][j - 1];
        }
    }
    (assign, total)
}

/// Kuhn's maximum bipartite matching over an adjacency-list bipartite
/// graph (left size n, right size n). Returns match_left (col per row,
/// usize::MAX if unmatched) and the matching size.
pub fn max_bipartite_matching(adj: &[Vec<usize>], n_right: usize)
                              -> (Vec<usize>, usize) {
    let n_left = adj.len();
    let mut match_right = vec![usize::MAX; n_right];
    let mut match_left = vec![usize::MAX; n_left];

    fn try_kuhn(
        v: usize,
        adj: &[Vec<usize>],
        used: &mut [bool],
        match_right: &mut [usize],
        match_left: &mut [usize],
    ) -> bool {
        for &to in &adj[v] {
            if !used[to] {
                used[to] = true;
                if match_right[to] == usize::MAX
                    || try_kuhn(match_right[to], adj, used, match_right,
                                match_left)
                {
                    match_right[to] = v;
                    match_left[v] = to;
                    return true;
                }
            }
        }
        false
    }

    let mut size = 0;
    for v in 0..n_left {
        let mut used = vec![false; n_right];
        if try_kuhn(v, adj, &mut used, &mut match_right, &mut match_left) {
            size += 1;
        }
    }
    (match_left, size)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hungarian_simple_3x3() {
        let cost = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        let (assign, total) = min_cost_assignment(&cost);
        assert_eq!(total, 5.0); // 1 + 2 + 2
        assert_eq!(assign, vec![1, 0, 2]);
    }

    #[test]
    fn hungarian_identity_when_diagonal_cheap() {
        let n = 6;
        let cost: Vec<Vec<f64>> = (0..n)
            .map(|i| (0..n).map(|j| if i == j { 0.0 } else { 10.0 }).collect())
            .collect();
        let (assign, total) = min_cost_assignment(&cost);
        assert_eq!(total, 0.0);
        assert_eq!(assign, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn hungarian_beats_greedy_on_adversarial_case() {
        // greedy (row-wise argmin) picks (0,0)=1 then forced (1,1)=100;
        // optimal is (0,1)=2 + (1,0)=2.
        let cost = vec![vec![1.0, 2.0], vec![2.0, 100.0]];
        let (_, total) = min_cost_assignment(&cost);
        assert_eq!(total, 4.0);
    }

    #[test]
    fn hungarian_matches_bruteforce_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for trial in 0..20 {
            let n = 2 + (trial % 4);
            let cost: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..n).map(|_| rng.below(100) as f64).collect())
                .collect();
            let (_, total) = min_cost_assignment(&cost);
            // brute force over permutations
            let mut perm: Vec<usize> = (0..n).collect();
            let mut best = f64::INFINITY;
            permute(&mut perm, 0, &mut |p| {
                let s: f64 = p.iter().enumerate()
                    .map(|(i, &j)| cost[i][j]).sum();
                if s < best {
                    best = s;
                }
            });
            assert_eq!(total, best, "n={n} cost={cost:?}");
        }
    }

    fn permute<F: FnMut(&[usize])>(xs: &mut Vec<usize>, k: usize, f: &mut F) {
        if k == xs.len() {
            f(xs);
            return;
        }
        for i in k..xs.len() {
            xs.swap(k, i);
            permute(xs, k + 1, f);
            xs.swap(k, i);
        }
    }

    #[test]
    fn kuhn_perfect_matching_exists() {
        // K3,3 minus some edges, still perfect
        let adj = vec![vec![0, 1], vec![1, 2], vec![0, 2]];
        let (ml, size) = max_bipartite_matching(&adj, 3);
        assert_eq!(size, 3);
        let mut cols: Vec<usize> = ml.clone();
        cols.sort_unstable();
        assert_eq!(cols, vec![0, 1, 2]);
    }

    #[test]
    fn kuhn_detects_infeasible() {
        // two rows compete for one column
        let adj = vec![vec![0], vec![0], vec![1]];
        let (_, size) = max_bipartite_matching(&adj, 2);
        assert_eq!(size, 2);
    }
}
