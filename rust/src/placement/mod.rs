//! Inference Execution Planner (paper §III-C, Algorithm 1): min-cut
//! balanced partitioning + resource-aware LBAP partition→fog mapping,
//! with the Hungarian/Kuhn assignment substrate and the Eq. (5)/(6)/(8)
//! cost model.

pub mod cost;
pub mod hungarian;
pub mod lbap;
pub mod planner;

pub use cost::{CostModel, PartStats};
pub use planner::{plan, MappingStrategy, Plan};
