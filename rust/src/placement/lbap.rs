//! Linear Bottleneck Assignment Problem solver — the IEP's partition→fog
//! mapping (paper §III-C, Alg. 1): minimize the MAXIMUM pair cost over all
//! perfect matchings.
//!
//! Implementation follows the paper's threshold scheme with the §III-C
//! "Discussion" optimization: binary search over the sorted distinct edge
//! weights (O(log n) feasibility tests instead of the O(n²) linear
//! descent), each test a Kuhn perfect-matching check on the
//! threshold-filtered bipartite graph.

use super::hungarian::max_bipartite_matching;

/// Solve min–max assignment over an n×n weight matrix.
/// Returns (assignment row→col, bottleneck value).
pub fn solve(weights: &[Vec<f64>]) -> (Vec<usize>, f64) {
    let n = weights.len();
    assert!(n > 0 && weights.iter().all(|r| r.len() == n));
    let mut thresholds: Vec<f64> =
        weights.iter().flatten().copied().collect();
    thresholds.sort_by(|a, b| a.partial_cmp(b).unwrap());
    thresholds.dedup();

    let feasible = |tau: f64| -> Option<Vec<usize>> {
        let adj: Vec<Vec<usize>> = weights
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .filter(|(_, &w)| w <= tau)
                    .map(|(j, _)| j)
                    .collect()
            })
            .collect();
        let (ml, size) = max_bipartite_matching(&adj, n);
        (size == n).then_some(ml)
    };

    // binary search the smallest feasible threshold
    let (mut lo, mut hi) = (0usize, thresholds.len() - 1);
    // the max threshold is always feasible iff a perfect matching exists
    let mut best = feasible(thresholds[hi])
        .expect("no perfect matching even with all edges");
    while lo < hi {
        let mid = (lo + hi) / 2;
        match feasible(thresholds[mid]) {
            Some(m) => {
                best = m;
                hi = mid;
            }
            None => lo = mid + 1,
        }
    }
    (best, thresholds[hi])
}

/// The paper's original linear threshold descent (Alg. 1 as printed) —
/// kept as the reference implementation for equivalence testing and the
/// O(n² · n³) vs O(n³ log n) ablation bench.
pub fn solve_linear_descent(weights: &[Vec<f64>]) -> (Vec<usize>, f64) {
    let n = weights.len();
    let mut thresholds: Vec<f64> =
        weights.iter().flatten().copied().collect();
    // priority queue of descending thresholds
    thresholds.sort_by(|a, b| b.partial_cmp(a).unwrap());
    thresholds.dedup();

    let mut best: Option<(Vec<usize>, f64)> = None;
    for &tau in &thresholds {
        let adj: Vec<Vec<usize>> = weights
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .filter(|(_, &w)| w <= tau)
                    .map(|(j, _)| j)
                    .collect()
            })
            .collect();
        let (ml, size) = max_bipartite_matching(&adj, n);
        if size == n {
            best = Some((ml, tau));
        } else {
            break; // smaller thresholds only remove edges
        }
    }
    best.expect("no perfect matching even with all edges")
}

/// Bottleneck value of a given assignment.
pub fn bottleneck(weights: &[Vec<f64>], assign: &[usize]) -> f64 {
    assign
        .iter()
        .enumerate()
        .map(|(i, &j)| weights[i][j])
        .fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::testkit::forall;

    #[test]
    fn minimizes_maximum_not_sum() {
        // sum-optimal picks (0,0)+(1,1) = 1+9; minmax prefers (0,1)+(1,0)
        // = max(5,5) over max(1,9).
        let w = vec![vec![1.0, 5.0], vec![5.0, 9.0]];
        let (assign, bn) = solve(&w);
        assert_eq!(bn, 5.0);
        assert_eq!(assign, vec![1, 0]);
    }

    #[test]
    fn binary_search_equals_linear_descent() {
        let mut rng = Rng::new(123);
        for _ in 0..30 {
            let n = 2 + rng.usize_below(6);
            let w: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..n).map(|_| rng.below(50) as f64).collect())
                .collect();
            let (_, a) = solve(&w);
            let (_, b) = solve_linear_descent(&w);
            assert_eq!(a, b, "w={w:?}");
        }
    }

    #[test]
    fn property_no_permutation_beats_bottleneck() {
        forall(
            7,
            40,
            |r| {
                let n = 2 + r.usize_below(4);
                (0..n)
                    .map(|_| (0..n).map(|_| r.below(30) as f64).collect())
                    .collect::<Vec<Vec<f64>>>()
            },
            |w| {
                let n = w.len();
                let (_, bn) = solve(w);
                // brute force all permutations
                let mut perm: Vec<usize> = (0..n).collect();
                let mut best = f64::INFINITY;
                fn go(
                    xs: &mut Vec<usize>,
                    k: usize,
                    w: &[Vec<f64>],
                    best: &mut f64,
                ) {
                    if k == xs.len() {
                        let m = xs
                            .iter()
                            .enumerate()
                            .map(|(i, &j)| w[i][j])
                            .fold(f64::NEG_INFINITY, f64::max);
                        if m < *best {
                            *best = m;
                        }
                        return;
                    }
                    for i in k..xs.len() {
                        xs.swap(k, i);
                        go(xs, k + 1, w, best);
                        xs.swap(k, i);
                    }
                }
                go(&mut perm, 0, w, &mut best);
                bn == best
            },
        );
    }

    #[test]
    fn handles_identical_weights() {
        let w = vec![vec![3.0; 4]; 4];
        let (assign, bn) = solve(&w);
        assert_eq!(bn, 3.0);
        let mut cols = assign.clone();
        cols.sort_unstable();
        assert_eq!(cols, vec![0, 1, 2, 3]);
    }
}
